//! Symmetry fast-path for pricing ring schedules on the torus.
//!
//! The event-driven simulator ([`NetSim`]) prices a bidirectional ring
//! step by scheduling every chip's two neighbor transfers over the shared
//! links. Under *uniform* payloads the full torus decomposes into
//! independent, identically-loaded rings: an X-phase message only crosses
//! X links of its own row, every row carries the same message multiset in
//! the same order, and rows share no links. The makespan of the whole
//! torus therefore equals the makespan of ONE representative ring — so
//! the fast path simulates a single `n x 1` ring instead of all `nx * ny`
//! chips, turning an O(nx*ny) simulation into O(ring length) while
//! producing bit-identical times (the `dist_invariants` suite pins the
//! fast path against the full simulation on 16/64/256/1024-chip tori).
//!
//! The fast path is exact ONLY under uniform payloads; a non-uniform
//! schedule (see the ROADMAP netsim item) breaks the row symmetry and
//! must fall back to the full event-driven simulation. That fallback is
//! now enforced: [`torus2d_gradsum_makespan_guarded`] checks per-chip
//! payload uniformity ([`payload_uniform`], bit-exact) and routes
//! non-uniform schedules through [`torus2d_gradsum_event_makespan`], the
//! whole-torus event-driven pricing of the same 4-phase schedule.

use super::cost::NetParams;
use super::sim::{Message, NetSim};
use super::torus::{Dir, Torus};

/// Event-driven makespan of one bidirectional ring step, priced from a
/// single representative ring of `ring_len` chips: every chip ships half
/// a `chunk_bytes` payload to each ring neighbor simultaneously.
///
/// On a 2-wide ring both half-chunks fold onto one link under
/// shortest-path routing and honestly serialize, exactly as they do on a
/// 2-wide torus dimension in the full simulation.
pub fn ring_step_makespan(ring_len: usize, chunk_bytes: f64, p: &NetParams) -> f64 {
    if ring_len <= 1 {
        return 0.0;
    }
    let ring = Torus::new(ring_len, 1);
    let mut sim = NetSim::new(ring, p.link_bw, p.link_latency);
    let msgs: Vec<Message> = ring
        .coords()
        .flat_map(|c| {
            [
                Message {
                    src: c,
                    dst: ring.step(c, Dir::XPlus),
                    bytes: chunk_bytes / 2.0,
                    ready_at: 0.0,
                },
                Message {
                    src: c,
                    dst: ring.step(c, Dir::XMinus),
                    bytes: chunk_bytes / 2.0,
                    ready_at: 0.0,
                },
            ]
        })
        .collect();
    sim.makespan(&msgs)
}

/// The full 4-phase bidirectional 2-D gradient-summation schedule priced
/// from one representative row ring and one column ring: reduce-scatter
/// along the X rings (`nx - 1` steps of `1/nx` chunks), reduce-scatter of
/// the shard along the Y rings (`ny - 1` steps of `1/(nx*ny)` chunks),
/// then the two matching all-gather phases in reverse. Identical step
/// composition to `scenario::gradsum_contention_makespan`'s full
/// event-driven form, with each step priced by [`ring_step_makespan`].
pub fn torus2d_gradsum_makespan(torus: Torus, payload_bytes: f64, p: &NetParams) -> f64 {
    if torus.chips() <= 1 {
        return 0.0;
    }
    let x_step = ring_step_makespan(torus.nx, payload_bytes / torus.nx as f64, p);
    let y_step = ring_step_makespan(torus.ny, payload_bytes / (torus.nx * torus.ny) as f64, p);
    // Phases 1+4 ride the X rings, phases 2+3 the Y rings.
    2.0 * ((torus.nx - 1) as f64 * x_step + (torus.ny - 1) as f64 * y_step)
}

/// A priced makespan plus which engine priced it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GuardedMakespan {
    pub seconds: f64,
    /// True when the symmetry fast path was exact (uniform payloads).
    pub fastpath: bool,
}

/// Whether every chip carries bit-identical payload bytes — the exact
/// precondition of the symmetry fast path.
pub fn payload_uniform(payloads: &[f64]) -> bool {
    payloads.windows(2).all(|w| w[0].to_bits() == w[1].to_bits())
}

/// The same 4-phase 2-D gradient-summation schedule as
/// [`torus2d_gradsum_makespan`], but priced by the full event-driven
/// simulation over the whole torus with per-chip payloads (indexed in
/// `Torus::id` row-major order). Needed when the payload schedule is
/// non-uniform: a heavy chip slows its own row/column while other rings
/// still finish early, which no single representative ring can express.
pub fn torus2d_gradsum_event_makespan(torus: Torus, payloads: &[f64], p: &NetParams) -> f64 {
    assert_eq!(payloads.len(), torus.chips(), "one payload per chip");
    if torus.chips() <= 1 {
        return 0.0;
    }
    let phase_step = |dir_plus: Dir, dir_minus: Dir, denom: f64| -> f64 {
        let msgs = gradsum_phase_messages(torus, payloads, dir_plus, dir_minus, denom);
        NetSim::new(torus, p.link_bw, p.link_latency).makespan(&msgs)
    };
    let x_step = if torus.nx > 1 {
        phase_step(Dir::XPlus, Dir::XMinus, torus.nx as f64)
    } else {
        0.0
    };
    let y_step = if torus.ny > 1 {
        phase_step(Dir::YPlus, Dir::YMinus, (torus.nx * torus.ny) as f64)
    } else {
        0.0
    };
    2.0 * ((torus.nx - 1) as f64 * x_step + (torus.ny - 1) as f64 * y_step)
}

/// One bidirectional gradsum phase step's message batch (every chip ships
/// half a `payload/denom` chunk to each neighbor along the phase axis) —
/// the unit both [`torus2d_gradsum_event_makespan`] and the concurrent
/// gradsum+halo pricing schedule.
fn gradsum_phase_messages(
    torus: Torus,
    payloads: &[f64],
    dir_plus: Dir,
    dir_minus: Dir,
    denom: f64,
) -> Vec<Message> {
    torus
        .coords()
        .flat_map(|c| {
            let half = payloads[torus.id(c)] / denom / 2.0;
            [
                Message { src: c, dst: torus.step(c, dir_plus), bytes: half, ready_at: 0.0 },
                Message { src: c, dst: torus.step(c, dir_minus), bytes: half, ready_at: 0.0 },
            ]
        })
        .collect()
}

/// One unidirectional 1-D ring step's message batch (row-major embedding:
/// every chip ships its `1/n` chunk to the next chip), matching the
/// scenario runner's 1-D contention model.
fn ring1d_step_messages(torus: Torus, payloads: &[f64]) -> Vec<Message> {
    let n = torus.chips();
    (0..n)
        .map(|i| Message {
            src: torus.coord(i),
            dst: torus.coord((i + 1) % n),
            bytes: payloads[i] / n as f64,
            ready_at: 0.0,
        })
        .collect()
}

/// The spatial-partition halo phase as a message batch: chips are
/// partitioned into consecutive row-major groups of `halo_group` chips
/// (one mp group each); every chip ships `halo_bytes` to the next member
/// of its group. Empty when the halo phase is inactive.
fn halo_messages(torus: Torus, halo_group: usize, halo_bytes: f64) -> Vec<Message> {
    let n = torus.chips();
    if halo_group <= 1 || !(halo_bytes > 0.0) {
        return Vec::new();
    }
    let mut msgs = Vec::new();
    let mut start = 0;
    while start < n {
        let size = halo_group.min(n - start);
        if size > 1 {
            for off in 0..size {
                msgs.push(Message {
                    src: torus.coord(start + off),
                    dst: torus.coord(start + (off + 1) % size),
                    bytes: halo_bytes,
                    ready_at: 0.0,
                });
            }
        }
        start += size;
    }
    msgs
}

/// Concurrent-phase contention pricing: the gradient-summation schedule
/// with the halo batch injected *into the same simulation* as the first
/// gradsum step, so the two phases share link bandwidth instead of being
/// priced independently.
///
/// The halo batch is appended after the gradsum messages, and the event
/// simulator's stable `ready_at` sort keeps the gradsum message times
/// unchanged — so the joint makespan is always ≥ the max of either phase
/// priced alone (adding traffic can only delay the added traffic). The
/// remaining `2(nx-1)+2(ny-1)-1` (2-D) or `2(n-1)-1` (1-D) steps run
/// clean. When the halo phase is inactive the price degenerates to the
/// plain (guarded) gradsum schedule; any active halo or non-uniform
/// payload schedule reports `fastpath: false`.
pub fn concurrent_gradsum_halo_makespan(
    torus: Torus,
    payloads: &[f64],
    halo_group: usize,
    halo_bytes: f64,
    two_d: bool,
    p: &NetParams,
) -> GuardedMakespan {
    assert_eq!(payloads.len(), torus.chips(), "one payload per chip");
    let halo = halo_messages(torus, halo_group, halo_bytes);
    let n = torus.chips();
    if halo.is_empty() {
        return if two_d {
            torus2d_gradsum_makespan_guarded(torus, payloads, p)
        } else {
            let msgs = ring1d_step_messages(torus, payloads);
            let one_step = if n > 1 {
                NetSim::new(torus, p.link_bw, p.link_latency).makespan(&msgs)
            } else {
                0.0
            };
            GuardedMakespan {
                seconds: one_step * (2 * n.saturating_sub(1)) as f64,
                fastpath: payload_uniform(payloads),
            }
        };
    }
    let seconds = if n <= 1 {
        NetSim::new(torus, p.link_bw, p.link_latency).makespan(&halo)
    } else if two_d {
        let step = |dir_plus: Dir, dir_minus: Dir, denom: f64| {
            gradsum_phase_messages(torus, payloads, dir_plus, dir_minus, denom)
        };
        let x_msgs = step(Dir::XPlus, Dir::XMinus, torus.nx as f64);
        let y_msgs = step(Dir::YPlus, Dir::YMinus, (torus.nx * torus.ny) as f64);
        let x_step = if torus.nx > 1 {
            NetSim::new(torus, p.link_bw, p.link_latency).makespan(&x_msgs)
        } else {
            0.0
        };
        let y_step = if torus.ny > 1 {
            NetSim::new(torus, p.link_bw, p.link_latency).makespan(&y_msgs)
        } else {
            0.0
        };
        let clean = 2.0 * ((torus.nx - 1) as f64 * x_step + (torus.ny - 1) as f64 * y_step);
        // The halo overlaps the first executed step (X phase, or Y on a
        // 1-wide torus); the rest of the schedule runs clean.
        let mut sim = NetSim::new(torus, p.link_bw, p.link_latency);
        if torus.nx > 1 {
            clean - x_step + sim.concurrent_makespan(&[&x_msgs, &halo])
        } else {
            clean - y_step + sim.concurrent_makespan(&[&y_msgs, &halo])
        }
    } else {
        let msgs = ring1d_step_messages(torus, payloads);
        let one_step = NetSim::new(torus, p.link_bw, p.link_latency).makespan(&msgs);
        let joint =
            NetSim::new(torus, p.link_bw, p.link_latency).concurrent_makespan(&[&msgs, &halo]);
        joint + one_step * (2 * (n - 1) - 1) as f64
    };
    GuardedMakespan { seconds, fastpath: false }
}

/// Guarded entry point: the symmetry fast path when the per-chip payload
/// schedule is uniform (bit-exact check), the full event-driven
/// simulation otherwise. Callers that previously reached for
/// [`torus2d_gradsum_makespan`] with an implicit uniformity assumption
/// should use this and read `fastpath` to see which engine priced them.
pub fn torus2d_gradsum_makespan_guarded(
    torus: Torus,
    payloads: &[f64],
    p: &NetParams,
) -> GuardedMakespan {
    assert_eq!(payloads.len(), torus.chips(), "one payload per chip");
    if payload_uniform(payloads) {
        let payload = payloads.first().copied().unwrap_or(0.0);
        GuardedMakespan { seconds: torus2d_gradsum_makespan(torus, payload, p), fastpath: true }
    } else {
        GuardedMakespan {
            seconds: torus2d_gradsum_event_makespan(torus, payloads, p),
            fastpath: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_chip_rings_are_free() {
        let p = NetParams::default();
        assert_eq!(ring_step_makespan(1, 1e6, &p), 0.0);
        assert_eq!(torus2d_gradsum_makespan(Torus::new(1, 1), 1e8, &p), 0.0);
    }

    #[test]
    fn ring_step_is_one_overlapped_transfer() {
        // On a ring wider than 2 every directed link carries exactly one
        // half-chunk: the step costs one transfer plus one hop latency.
        let p = NetParams::default();
        let t = ring_step_makespan(8, 1e6, &p);
        let expect = 0.5e6 / p.link_bw + p.link_latency;
        assert!((t - expect).abs() < 1e-15, "{t} vs {expect}");
    }

    #[test]
    fn two_wide_ring_serializes_the_half_chunks() {
        // nx = 2: both half-chunks route over the same +x link.
        let p = NetParams::default();
        let t = ring_step_makespan(2, 1e6, &p);
        let expect = 2.0 * 0.5e6 / p.link_bw + p.link_latency;
        assert!((t - expect).abs() < 1e-15, "{t} vs {expect}");
    }

    #[test]
    fn pod_schedule_positive_and_monotone_in_payload() {
        let p = NetParams::default();
        let torus = Torus::for_chips(1024);
        let small = torus2d_gradsum_makespan(torus, 1e6, &p);
        let large = torus2d_gradsum_makespan(torus, 1e8, &p);
        assert!(small > 0.0);
        assert!(large > small);
    }

    #[test]
    fn uniform_payloads_take_the_fast_path_exactly() {
        let p = NetParams::default();
        let torus = Torus::for_chips(64);
        let payloads = vec![1e7; torus.chips()];
        let g = torus2d_gradsum_makespan_guarded(torus, &payloads, &p);
        assert!(g.fastpath);
        assert_eq!(g.seconds, torus2d_gradsum_makespan(torus, 1e7, &p));
    }

    #[test]
    fn event_engine_matches_fastpath_under_uniform_payloads() {
        let p = NetParams::default();
        for chips in [16usize, 64] {
            let torus = Torus::for_chips(chips);
            let payloads = vec![2e6; torus.chips()];
            let event = torus2d_gradsum_event_makespan(torus, &payloads, &p);
            let fast = torus2d_gradsum_makespan(torus, 2e6, &p);
            assert!(
                (event - fast).abs() <= 1e-9 * fast.max(1.0),
                "{chips} chips: event {event} vs fastpath {fast}"
            );
        }
    }

    #[test]
    fn non_uniform_payloads_fall_back_to_the_event_engine() {
        let p = NetParams::default();
        let torus = Torus::for_chips(16);
        let mut payloads = vec![1e6; torus.chips()];
        payloads[5] = 8e6; // one heavy chip breaks the row symmetry
        assert!(!payload_uniform(&payloads));
        let g = torus2d_gradsum_makespan_guarded(torus, &payloads, &p);
        assert!(!g.fastpath);
        assert_eq!(g.seconds, torus2d_gradsum_event_makespan(torus, &payloads, &p));
        // The heavy chip can only slow the schedule down.
        let uniform = torus2d_gradsum_makespan(torus, 1e6, &p);
        assert!(g.seconds >= uniform - 1e-12, "{} vs uniform {uniform}", g.seconds);
    }

    #[test]
    fn zero_halo_concurrent_price_degenerates_to_the_plain_schedule() {
        let p = NetParams::default();
        let torus = Torus::for_chips(64);
        let payloads = vec![1e7; torus.chips()];
        // No halo bytes: bit-identical to the guarded fast-path price.
        let g = concurrent_gradsum_halo_makespan(torus, &payloads, 4, 0.0, true, &p);
        assert!(g.fastpath);
        assert_eq!(g.seconds.to_bits(), torus2d_gradsum_makespan(torus, 1e7, &p).to_bits());
        // A halo group of 1 has nobody to exchange with: same degeneration.
        let g1 = concurrent_gradsum_halo_makespan(torus, &payloads, 1, 5e6, true, &p);
        assert!(g1.fastpath);
        assert_eq!(g1.seconds.to_bits(), g.seconds.to_bits());
    }

    #[test]
    fn concurrent_halo_never_beats_either_phase_alone() {
        let p = NetParams::default();
        let torus = Torus::for_chips(64);
        let payloads = vec![1e7; torus.chips()];
        let halo_alone =
            NetSim::new(torus, p.link_bw, p.link_latency).makespan(&halo_messages(torus, 4, 5e6));
        assert!(halo_alone > 0.0);
        for two_d in [true, false] {
            let clean =
                concurrent_gradsum_halo_makespan(torus, &payloads, 4, 0.0, two_d, &p).seconds;
            let joint = concurrent_gradsum_halo_makespan(torus, &payloads, 4, 5e6, two_d, &p);
            assert!(!joint.fastpath, "shared-link pricing must report fastpath: false");
            assert!(
                joint.seconds > clean,
                "two_d={two_d}: joint {} must exceed the clean schedule {clean}",
                joint.seconds
            );
            assert!(joint.seconds >= halo_alone, "two_d={two_d}");
        }
    }

    #[test]
    fn payload_uniformity_is_bit_exact() {
        assert!(payload_uniform(&[]));
        assert!(payload_uniform(&[3.0]));
        assert!(payload_uniform(&[3.0, 3.0, 3.0]));
        assert!(!payload_uniform(&[3.0, 3.0 + 1e-12]));
    }
}
