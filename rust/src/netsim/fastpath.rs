//! Symmetry fast-path for pricing ring schedules on the torus.
//!
//! The event-driven simulator ([`NetSim`]) prices a bidirectional ring
//! step by scheduling every chip's two neighbor transfers over the shared
//! links. Under *uniform* payloads the full torus decomposes into
//! independent, identically-loaded rings: an X-phase message only crosses
//! X links of its own row, every row carries the same message multiset in
//! the same order, and rows share no links. The makespan of the whole
//! torus therefore equals the makespan of ONE representative ring — so
//! the fast path simulates a single `n x 1` ring instead of all `nx * ny`
//! chips, turning an O(nx*ny) simulation into O(ring length) while
//! producing bit-identical times (the `dist_invariants` suite pins the
//! fast path against the full simulation on 16/64/256/1024-chip tori).
//!
//! The fast path is exact ONLY under uniform payloads; a non-uniform
//! schedule (see the ROADMAP netsim item) breaks the row symmetry and
//! must fall back to the full event-driven simulation.

use super::cost::NetParams;
use super::sim::{Message, NetSim};
use super::torus::{Dir, Torus};

/// Event-driven makespan of one bidirectional ring step, priced from a
/// single representative ring of `ring_len` chips: every chip ships half
/// a `chunk_bytes` payload to each ring neighbor simultaneously.
///
/// On a 2-wide ring both half-chunks fold onto one link under
/// shortest-path routing and honestly serialize, exactly as they do on a
/// 2-wide torus dimension in the full simulation.
pub fn ring_step_makespan(ring_len: usize, chunk_bytes: f64, p: &NetParams) -> f64 {
    if ring_len <= 1 {
        return 0.0;
    }
    let ring = Torus::new(ring_len, 1);
    let mut sim = NetSim::new(ring, p.link_bw, p.link_latency);
    let msgs: Vec<Message> = ring
        .coords()
        .flat_map(|c| {
            [
                Message {
                    src: c,
                    dst: ring.step(c, Dir::XPlus),
                    bytes: chunk_bytes / 2.0,
                    ready_at: 0.0,
                },
                Message {
                    src: c,
                    dst: ring.step(c, Dir::XMinus),
                    bytes: chunk_bytes / 2.0,
                    ready_at: 0.0,
                },
            ]
        })
        .collect();
    sim.makespan(&msgs)
}

/// The full 4-phase bidirectional 2-D gradient-summation schedule priced
/// from one representative row ring and one column ring: reduce-scatter
/// along the X rings (`nx - 1` steps of `1/nx` chunks), reduce-scatter of
/// the shard along the Y rings (`ny - 1` steps of `1/(nx*ny)` chunks),
/// then the two matching all-gather phases in reverse. Identical step
/// composition to `scenario::gradsum_contention_makespan`'s full
/// event-driven form, with each step priced by [`ring_step_makespan`].
pub fn torus2d_gradsum_makespan(torus: Torus, payload_bytes: f64, p: &NetParams) -> f64 {
    if torus.chips() <= 1 {
        return 0.0;
    }
    let x_step = ring_step_makespan(torus.nx, payload_bytes / torus.nx as f64, p);
    let y_step = ring_step_makespan(torus.ny, payload_bytes / (torus.nx * torus.ny) as f64, p);
    // Phases 1+4 ride the X rings, phases 2+3 the Y rings.
    2.0 * ((torus.nx - 1) as f64 * x_step + (torus.ny - 1) as f64 * y_step)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_chip_rings_are_free() {
        let p = NetParams::default();
        assert_eq!(ring_step_makespan(1, 1e6, &p), 0.0);
        assert_eq!(torus2d_gradsum_makespan(Torus::new(1, 1), 1e8, &p), 0.0);
    }

    #[test]
    fn ring_step_is_one_overlapped_transfer() {
        // On a ring wider than 2 every directed link carries exactly one
        // half-chunk: the step costs one transfer plus one hop latency.
        let p = NetParams::default();
        let t = ring_step_makespan(8, 1e6, &p);
        let expect = 0.5e6 / p.link_bw + p.link_latency;
        assert!((t - expect).abs() < 1e-15, "{t} vs {expect}");
    }

    #[test]
    fn two_wide_ring_serializes_the_half_chunks() {
        // nx = 2: both half-chunks route over the same +x link.
        let p = NetParams::default();
        let t = ring_step_makespan(2, 1e6, &p);
        let expect = 2.0 * 0.5e6 / p.link_bw + p.link_latency;
        assert!((t - expect).abs() < 1e-15, "{t} vs {expect}");
    }

    #[test]
    fn pod_schedule_positive_and_monotone_in_payload() {
        let p = NetParams::default();
        let torus = Torus::for_chips(1024);
        let small = torus2d_gradsum_makespan(torus, 1e6, &p);
        let large = torus2d_gradsum_makespan(torus, 1e8, &p);
        assert!(small > 0.0);
        assert!(large > small);
    }
}
