//! TPU-v3 pod interconnect simulation (paper Figs. 1-2): 2-D torus
//! topology, analytic collective cost model, and an event-driven
//! link-contention simulator that validates the analytic assumptions.
//!
//! Beyond the paper's single pod, the [`topology`] module models
//! *hierarchical* pod groups ([`PodSpec`]/[`TopologySpec`]): N identical
//! 2-D tori joined by inter-pod links at a fraction of the torus link
//! bandwidth, with two cross-pod gradient-summation strategies
//! ([`CrossPodStrategy`]). The event simulator supports per-link
//! bandwidth overrides ([`NetSim::set_link_bw`]) for the slow boundary
//! links and concurrent-phase injection ([`NetSim::concurrent_makespan`])
//! so overlapping gradsum and halo payloads share link bandwidth instead
//! of being priced independently. The `fastpath` symmetry shortcut stays
//! exact only for uniform payloads on a collapsed (single-pod) spec;
//! every other case routes through the guarded, event-driven entry
//! points and reports `fastpath: false`.

pub mod cost;
pub mod fastpath;
pub mod sim;
pub mod topology;
pub mod torus;

pub use cost::{ArAlgo, CostModel, GradSumModel, NetParams};
pub use fastpath::{
    concurrent_gradsum_halo_makespan, payload_uniform, ring_step_makespan,
    torus2d_gradsum_event_makespan, torus2d_gradsum_makespan, torus2d_gradsum_makespan_guarded,
    GuardedMakespan,
};
pub use sim::{Message, NetSim};
pub use topology::{
    cross_pod_ring_seconds, pod_group_gradsum_makespan, pod_group_gradsum_makespan_guarded,
    schedule_fingerprint, CrossPodStrategy, Placement, PodSpec, TopologySpec,
};
pub use torus::{Coord, Dir, Link, Torus};
