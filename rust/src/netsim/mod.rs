//! TPU-v3 pod interconnect simulation (paper Figs. 1-2): 2-D torus
//! topology, analytic collective cost model, and an event-driven
//! link-contention simulator that validates the analytic assumptions.

pub mod cost;
pub mod fastpath;
pub mod sim;
pub mod torus;

pub use cost::{ArAlgo, CostModel, GradSumModel, NetParams};
pub use fastpath::{
    payload_uniform, ring_step_makespan, torus2d_gradsum_event_makespan,
    torus2d_gradsum_makespan, torus2d_gradsum_makespan_guarded, GuardedMakespan,
};
pub use sim::{Message, NetSim};
pub use torus::{Coord, Dir, Link, Torus};
