//! Analytic cost model for collectives on the TPU-v3 torus, including the
//! paper's pipelined non-contiguous gradient summation (§2 "Optimize
//! gradient summation": "over 1.5x speedup of gradient summation throughput
//! in the ResNet-50 model").
//!
//! Constants are public TPU-v3 figures; absolute times are estimates, but
//! the *ratios* the paper reports (pipelined vs. serial, 1-D vs. 2-D) fall
//! out of the structure, which is what the benches assert.
//!
//! The simulator consumes this model through `costs::GradSumPhase`, which
//! builds the [`CostModel`] over the *participating* torus of a layout
//! (surplus chips carry no all-reduce traffic); the event-driven
//! contention check in `scenario::gradsum_contention_makespan` validates
//! the 4-phase 2-D schedule's overlap assumptions link by link.

use super::torus::Torus;

/// Per-link and per-chip hardware parameters.
#[derive(Clone, Copy, Debug)]
pub struct NetParams {
    /// One torus link, one direction, bytes/s.
    pub link_bw: f64,
    /// Per-message link latency, seconds.
    pub link_latency: f64,
    /// HBM bandwidth per chip, bytes/s (gathers/scatters of gradient
    /// fragments contend with this).
    pub hbm_bw: f64,
    /// Fixed software overhead to launch one collective phase, seconds.
    pub phase_overhead: f64,
    /// DMA descriptor setup per non-contiguous gradient fragment, seconds
    /// (the cost the paper's pipelining hides).
    pub dma_setup: f64,
}

impl Default for NetParams {
    fn default() -> NetParams {
        NetParams {
            link_bw: 70e9,       // ~70 GB/s per ICI link direction
            link_latency: 1e-6,  // ~1 us neighbor hop
            hbm_bw: 900e9,       // 900 GB/s HBM per chip (paper Fig. 1)
            phase_overhead: 5e-6,
            dma_setup: 3e-6,
        }
    }
}

/// Which all-reduce schedule to cost.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ArAlgo {
    /// Single ring over all n chips (the pre-[19] baseline).
    Ring1D,
    /// The paper's 2-D scheme: reduce-scatter along X rings, reduce-scatter
    /// along Y rings, then all-gathers in reverse — both torus dimensions'
    /// links busy simultaneously.
    Torus2D,
}

#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub torus: Torus,
    pub params: NetParams,
}

impl CostModel {
    pub fn new(torus: Torus, params: NetParams) -> CostModel {
        CostModel { torus, params }
    }

    /// Ring all-reduce time over `n` nodes for `bytes` per node, using both
    /// ring directions (torus links are bidirectional → 2x bandwidth).
    fn ring_ar(&self, n: usize, bytes: f64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let eff_bw = 2.0 * self.params.link_bw; // bidirectional ring
        let steps = 2 * (n - 1); // reduce-scatter + all-gather
        let frac = (n - 1) as f64 / n as f64;
        2.0 * frac * bytes / eff_bw + steps as f64 * self.params.link_latency
    }

    /// All-reduce of `bytes` (per chip) with the chosen schedule.
    pub fn all_reduce(&self, algo: ArAlgo, bytes: f64) -> f64 {
        match algo {
            ArAlgo::Ring1D => self.ring_ar(self.torus.chips(), bytes),
            ArAlgo::Torus2D => {
                let (nx, ny) = (self.torus.nx, self.torus.ny);
                // Phase 1: concurrent reduce-scatter on every X ring.
                // Phase 2: reduce-scatter of the 1/nx shard on Y rings.
                // Phases 3-4: matching all-gathers. Each phase is a ring
                // operation on a shrinking payload.
                let eff_bw = 2.0 * self.params.link_bw;
                let fx = (nx - 1) as f64 / nx as f64;
                let fy = (ny - 1) as f64 / ny as f64;
                let bw_term = 2.0 * (fx * bytes + fy * bytes / nx as f64) / eff_bw;
                let lat_steps = 2 * ((nx - 1) + (ny - 1));
                bw_term
                    + lat_steps as f64 * self.params.link_latency
                    + 4.0 * self.params.phase_overhead
            }
        }
    }

    /// All-gather: each chip starts with `bytes / n` and ends with `bytes`.
    pub fn all_gather(&self, bytes_total: f64) -> f64 {
        let n = self.torus.chips();
        if n <= 1 {
            return 0.0;
        }
        let eff_bw = 2.0 * self.params.link_bw;
        let frac = (n - 1) as f64 / n as f64;
        frac * bytes_total / eff_bw
            + (n - 1) as f64 * self.params.link_latency
            + self.params.phase_overhead
    }

    /// Reduce-scatter (half of an all-reduce).
    pub fn reduce_scatter(&self, bytes: f64) -> f64 {
        let n = self.torus.chips();
        if n <= 1 {
            return 0.0;
        }
        let eff_bw = 2.0 * self.params.link_bw;
        let frac = (n - 1) as f64 / n as f64;
        frac * bytes / eff_bw
            + (n - 1) as f64 * self.params.link_latency
            + self.params.phase_overhead
    }

    /// Halo exchange with spatial-partition neighbors (§2 spatial
    /// partitioning): all neighbor transfers overlap, so the time is the
    /// max single-neighbor transfer.
    pub fn halo_exchange(&self, bytes_per_neighbor: f64, neighbors: usize) -> f64 {
        if neighbors == 0 {
            return 0.0;
        }
        bytes_per_neighbor / self.params.link_bw
            + self.params.link_latency
            + self.params.phase_overhead
    }
}

/// Gradient-summation schedule over a model's (non-contiguous) gradient
/// tensors — the §2 optimization. `tensor_bytes` is the per-tensor gradient
/// size distribution (e.g. ResNet-50's 161 tensors).
pub struct GradSumModel<'a> {
    pub cost: &'a CostModel,
    pub algo: ArAlgo,
}

impl<'a> GradSumModel<'a> {
    /// Time to gather (or scatter) every fragment between non-contiguous
    /// HBM storage and the contiguous staging buffer: each fragment pays a
    /// DMA descriptor setup plus its stream time.
    fn hbm_stream(&self, tensor_bytes: &[f64]) -> f64 {
        let p = &self.cost.params;
        let total: f64 = tensor_bytes.iter().sum();
        tensor_bytes.len() as f64 * p.dma_setup + total / p.hbm_bw
    }

    /// Per-tensor schedule (pre-[19] TF behaviour): one all-reduce op per
    /// gradient tensor, each paying full latency and phase overheads.
    pub fn per_tensor(&self, tensor_bytes: &[f64]) -> f64 {
        let p = &self.cost.params;
        tensor_bytes
            .iter()
            .map(|&b| {
                p.dma_setup + b / p.hbm_bw
                    + self.cost.all_reduce(self.algo, b)
                    + p.dma_setup + b / p.hbm_bw
            })
            .sum()
    }

    /// Serial fused schedule (the paper's baseline): ONE all-reduce over
    /// the aggregate payload, but the gather of all fragments completes
    /// before the network reduction starts, and the scatter only starts
    /// after the broadcast finishes. The non-contiguous gather/scatter
    /// streams are fully exposed.
    pub fn serial(&self, tensor_bytes: &[f64]) -> f64 {
        let total: f64 = tensor_bytes.iter().sum();
        self.hbm_stream(tensor_bytes)
            + self.cost.all_reduce(self.algo, total)
            + self.hbm_stream(tensor_bytes)
    }

    /// Pipelined schedule (the paper's optimization): gathers from
    /// non-contiguous HBM overlap the summation of network packets, and
    /// scatters overlap the broadcast-phase transfers. Steady state is the
    /// max of the three streams; one gather and one scatter fragment are
    /// exposed at the ends.
    pub fn pipelined(&self, tensor_bytes: &[f64]) -> f64 {
        let p = &self.cost.params;
        let total: f64 = tensor_bytes.iter().sum();
        let hbm = self.hbm_stream(tensor_bytes);
        let net_stream = self.cost.all_reduce(self.algo, total);
        let exposed = 2.0 * p.dma_setup
            + (tensor_bytes.first().copied().unwrap_or(0.0)
                + tensor_bytes.last().copied().unwrap_or(0.0))
                / p.hbm_bw;
        hbm.max(net_stream) + exposed
    }

    /// Paper headline: pipelined speedup over the serial fused baseline.
    pub fn speedup(&self, tensor_bytes: &[f64]) -> f64 {
        self.serial(tensor_bytes) / self.pipelined(tensor_bytes)
    }
}

/// ResNet-50-shaped gradient size distribution (bytes): 53 conv kernels of
/// growing width + BN scale/bias pairs + the fc layer — 161 tensors,
/// ≈102 MB total, matching the real model's parameter census.
pub fn resnet50_gradient_bytes() -> Vec<f64> {
    let mut v = Vec::new();
    // conv1 7x7x3x64
    v.push(7.0 * 7.0 * 3.0 * 64.0 * 4.0);
    let stage_blocks = [3usize, 4, 6, 3];
    let widths = [(64.0, 256.0), (128.0, 512.0), (256.0, 1024.0), (512.0, 2048.0)];
    for (s, &blocks) in stage_blocks.iter().enumerate() {
        let (w, wout) = widths[s];
        for b in 0..blocks {
            let win = if b == 0 { if s == 0 { 64.0 } else { widths[s - 1].1 } } else { wout };
            v.push(win * w * 4.0); // 1x1 reduce
            v.push(9.0 * w * w * 4.0); // 3x3
            v.push(w * wout * 4.0); // 1x1 expand
            if b == 0 {
                v.push(win * wout * 4.0); // projection shortcut
            }
        }
    }
    // BN scale+bias per conv (approximate census)
    let convs = v.len();
    for _ in 0..convs * 2 {
        v.push(256.0 * 4.0);
    }
    v.push(2048.0 * 1000.0 * 4.0); // fc
    v.push(1000.0 * 4.0); // fc bias
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(chips: usize) -> CostModel {
        CostModel::new(Torus::for_chips(chips), NetParams::default())
    }

    #[test]
    fn all_reduce_zero_on_single_chip() {
        let m = CostModel::new(Torus::new(1, 1), NetParams::default());
        assert_eq!(m.all_reduce(ArAlgo::Ring1D, 1e6), 0.0);
    }

    #[test]
    fn torus2d_beats_ring_at_pod_scale() {
        // §2 / [19]: at 1024 chips the 1-D ring's latency term (2046 hops)
        // dwarfs the 2-D scheme's (124 hops).
        let m = model(1024);
        let bytes = 100e6; // ResNet-50 gradients
        let ring = m.all_reduce(ArAlgo::Ring1D, bytes);
        let torus = m.all_reduce(ArAlgo::Torus2D, bytes);
        assert!(torus < ring, "2-D {torus} !< ring {ring}");
        assert!(ring / torus > 2.0, "expected >2x at pod scale, got {}", ring / torus);
    }

    #[test]
    fn ring_fine_at_small_scale() {
        // On 4 chips the schedules are within ~2x — the 2-D scheme is a
        // large-scale optimization.
        let m = model(4);
        let ring = m.all_reduce(ArAlgo::Ring1D, 100e6);
        let torus = m.all_reduce(ArAlgo::Torus2D, 100e6);
        assert!(ring < 2.0 * torus);
    }

    #[test]
    fn all_reduce_monotone_in_bytes() {
        let m = model(256);
        let mut prev = 0.0;
        for mb in [1.0, 10.0, 100.0, 1000.0] {
            let t = m.all_reduce(ArAlgo::Torus2D, mb * 1e6);
            assert!(t > prev);
            prev = t;
        }
    }

    #[test]
    fn pipelined_gradsum_hits_paper_speedup() {
        // Paper §2: "over 1.5x speedup of gradient summation throughput in
        // the ResNet-50 model on TPU-v3 pods."
        let m = model(1024);
        let gs = GradSumModel { cost: &m, algo: ArAlgo::Torus2D };
        let tensors = resnet50_gradient_bytes();
        let speedup = gs.speedup(&tensors);
        assert!(speedup > 1.5, "speedup {speedup}");
        assert!(speedup < 3.0, "speedup implausible: {speedup}");
    }

    #[test]
    fn per_tensor_schedule_is_worst() {
        let m = model(1024);
        let gs = GradSumModel { cost: &m, algo: ArAlgo::Torus2D };
        let tensors = resnet50_gradient_bytes();
        assert!(gs.per_tensor(&tensors) > gs.serial(&tensors));
        assert!(gs.serial(&tensors) > gs.pipelined(&tensors));
    }

    #[test]
    fn resnet50_census_plausible() {
        let tensors = resnet50_gradient_bytes();
        let total: f64 = tensors.iter().sum();
        // ~25.6M params * 4 bytes ≈ 102 MB; census within 15%.
        assert!((total - 102.4e6).abs() < 16e6, "total={total}");
        assert!(tensors.len() > 150, "len={}", tensors.len());
    }

    #[test]
    fn pipelined_never_slower() {
        let m = model(64);
        let gs = GradSumModel { cost: &m, algo: ArAlgo::Torus2D };
        for tensors in [vec![1e6], vec![1e3; 100], vec![5e7, 1e3, 1e3]] {
            assert!(gs.speedup(&tensors) >= 0.99, "{tensors:?}");
        }
    }

    #[test]
    fn halo_overlaps_neighbors() {
        let m = model(16);
        // 4 neighbors exchanging 1 MB each takes the same time as 1.
        let t1 = m.halo_exchange(1e6, 1);
        let t4 = m.halo_exchange(1e6, 4);
        assert_eq!(t1, t4);
    }
}
