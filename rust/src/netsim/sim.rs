//! Event-driven link-contention simulator for the 2-D torus.
//!
//! The analytic model (cost.rs) assumes perfectly overlapped rings; this
//! simulator checks those assumptions by actually scheduling messages over
//! shared links. Store-and-forward at message granularity with
//! dimension-ordered routing: each directed link serializes the messages
//! crossing it; a message's hop can only begin once (a) the message has
//! fully arrived at the hop's source and (b) the link is free.
//!
//! Used by the collectives tests to verify that the 2-D schedule produces
//! no link hot-spots (every X ring and Y ring loads uniformly), and by the
//! gradsum bench to sanity-check the pipelining win under contention.

use std::collections::HashMap;

use super::torus::{Coord, Dir, Link, Torus};

#[derive(Clone, Copy, Debug)]
pub struct Message {
    pub src: Coord,
    pub dst: Coord,
    pub bytes: f64,
    /// Earliest time the message may leave its source.
    pub ready_at: f64,
}

#[derive(Clone, Copy, Debug)]
pub struct Delivery {
    pub msg: Message,
    pub arrived_at: f64,
}

#[derive(Clone, Debug, Default)]
pub struct LinkStats {
    /// Total bytes that crossed each directed link.
    pub bytes: HashMap<(usize, usize, u8), f64>,
}

impl LinkStats {
    fn key(t: &Torus, l: Link) -> (usize, usize, u8) {
        (t.id(l.from), 0, l.dir as u8)
    }
    pub fn max_bytes(&self) -> f64 {
        self.bytes.values().cloned().fold(0.0, f64::max)
    }
    pub fn min_bytes(&self) -> f64 {
        self.bytes.values().cloned().fold(f64::INFINITY, f64::min)
    }
    /// Hot-spot factor: max/mean link load (1.0 = perfectly uniform).
    pub fn hotspot(&self) -> f64 {
        if self.bytes.is_empty() {
            return 1.0;
        }
        let mean: f64 = self.bytes.values().sum::<f64>() / self.bytes.len() as f64;
        self.max_bytes() / mean
    }
}

pub struct NetSim {
    pub torus: Torus,
    pub link_bw: f64,
    pub link_latency: f64,
    link_free: HashMap<(usize, u8), f64>,
    /// Per-directed-link bandwidth overrides (hierarchical topologies
    /// slow the pod-boundary links down without forking the simulator).
    bw_overrides: HashMap<(usize, u8), f64>,
    pub stats: LinkStats,
}

impl NetSim {
    pub fn new(torus: Torus, link_bw: f64, link_latency: f64) -> NetSim {
        NetSim {
            torus,
            link_bw,
            link_latency,
            link_free: HashMap::new(),
            bw_overrides: HashMap::new(),
            stats: LinkStats::default(),
        }
    }

    /// Override one directed link's bandwidth (e.g. a pod-boundary link
    /// running at the inter-pod rate). Links without an override keep
    /// the uniform `link_bw`, bit-identically to the pre-override model.
    pub fn set_link_bw(&mut self, from: Coord, dir: Dir, bw: f64) {
        assert!(bw > 0.0, "link bandwidth must be positive");
        self.bw_overrides.insert((self.torus.id(from), dir as u8), bw);
    }

    fn bw_of(&self, key: (usize, u8)) -> f64 {
        self.bw_overrides.get(&key).copied().unwrap_or(self.link_bw)
    }

    /// Run a batch of messages; returns deliveries (same order as input).
    /// Messages are injected in `ready_at` order (FIFO per link thereafter).
    pub fn run(&mut self, messages: &[Message]) -> Vec<Delivery> {
        let mut order: Vec<usize> = (0..messages.len()).collect();
        order.sort_by(|&a, &b| messages[a].ready_at.total_cmp(&messages[b].ready_at));
        let mut out = vec![None; messages.len()];
        for idx in order {
            let m = messages[idx];
            let mut t = m.ready_at;
            for link in self.torus.route(m.src, m.dst) {
                let key = (self.torus.id(link.from), link.dir as u8);
                let free = self.link_free.get(&key).copied().unwrap_or(0.0);
                let depart = t.max(free);
                let xfer = m.bytes / self.bw_of(key);
                self.link_free.insert(key, depart + xfer);
                t = depart + xfer + self.link_latency;
                *self.stats.bytes.entry(LinkStats::key(&self.torus, link)).or_insert(0.0) +=
                    m.bytes;
            }
            out[idx] = Some(Delivery { msg: m, arrived_at: t });
        }
        out.into_iter().map(|d| d.unwrap()).collect()
    }

    /// Completion time of the whole batch.
    pub fn makespan(&mut self, messages: &[Message]) -> f64 {
        self.run(messages).iter().map(|d| d.arrived_at).fold(0.0, f64::max)
    }

    /// Completion time of several phases injected *concurrently* into
    /// one simulation, so overlapping phases share link bandwidth
    /// instead of being priced independently. Injection order is the
    /// phase order: the stable `ready_at` sort keeps an earlier phase's
    /// messages ahead of a later phase's at equal ready times, so adding
    /// a phase never speeds up the phases before it — the joint makespan
    /// is always ≥ the max of each phase priced alone.
    pub fn concurrent_makespan(&mut self, phases: &[&[Message]]) -> f64 {
        let all: Vec<Message> = phases.iter().flat_map(|ph| ph.iter().copied()).collect();
        self.makespan(&all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(nx: usize, ny: usize) -> NetSim {
        NetSim::new(Torus::new(nx, ny), 1e9, 1e-6)
    }

    fn msg(sx: usize, sy: usize, dx: usize, dy: usize, bytes: f64, t: f64) -> Message {
        Message {
            src: Coord { x: sx, y: sy },
            dst: Coord { x: dx, y: dy },
            bytes,
            ready_at: t,
        }
    }

    #[test]
    fn single_hop_time() {
        let mut s = sim(4, 4);
        let d = s.run(&[msg(0, 0, 1, 0, 1e6, 0.0)]);
        let expect = 1e6 / 1e9 + 1e-6;
        assert!((d[0].arrived_at - expect).abs() < 1e-12);
    }

    #[test]
    fn shared_link_serializes() {
        let mut s = sim(4, 4);
        let d = s.run(&[msg(0, 0, 1, 0, 1e6, 0.0), msg(0, 0, 1, 0, 1e6, 0.0)]);
        let t1 = 1e6 / 1e9 + 1e-6;
        assert!((d[0].arrived_at - t1).abs() < 1e-12);
        assert!((d[1].arrived_at - (2.0 * 1e6 / 1e9 + 1e-6)).abs() < 1e-12);
    }

    #[test]
    fn disjoint_routes_overlap() {
        let mut s = sim(4, 4);
        let batch = [msg(0, 0, 1, 0, 1e6, 0.0), msg(0, 1, 1, 1, 1e6, 0.0)];
        let mk = s.makespan(&batch);
        assert!((mk - (1e6 / 1e9 + 1e-6)).abs() < 1e-12);
    }

    #[test]
    fn multi_hop_accumulates_latency() {
        let mut s = sim(8, 8);
        let d = s.run(&[msg(0, 0, 3, 2, 1e3, 0.0)]);
        // 5 hops, each (1e3/1e9 + 1us), store-and-forward.
        let expect = 5.0 * (1e3 / 1e9 + 1e-6);
        assert!((d[0].arrived_at - expect).abs() < 1e-12);
    }

    #[test]
    fn neighbor_ring_exchange_is_uniform() {
        // One simultaneous +x neighbor send per chip = a ring step; no
        // link should carry more than any other.
        let mut s = sim(8, 1);
        let batch: Vec<Message> =
            (0..8).map(|x| msg(x, 0, (x + 1) % 8, 0, 1e6, 0.0)).collect();
        let mk = s.makespan(&batch);
        assert!((mk - (1e6 / 1e9 + 1e-6)).abs() < 1e-12, "ring step must fully overlap");
        assert!((s.stats.hotspot() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ready_at_respected() {
        let mut s = sim(4, 1);
        let d = s.run(&[msg(0, 0, 1, 0, 1e6, 5.0)]);
        assert!(d[0].arrived_at >= 5.0);
    }

    #[test]
    fn link_bw_override_slows_only_that_link() {
        let mut s = sim(4, 1);
        s.set_link_bw(Coord { x: 0, y: 0 }, crate::netsim::Dir::XPlus, 0.5e9);
        let d = s.run(&[msg(0, 0, 1, 0, 1e6, 0.0), msg(1, 0, 2, 0, 1e6, 0.0)]);
        assert!((d[0].arrived_at - (1e6 / 0.5e9 + 1e-6)).abs() < 1e-12, "overridden link");
        assert!((d[1].arrived_at - (1e6 / 1e9 + 1e-6)).abs() < 1e-12, "untouched link");
    }

    #[test]
    fn concurrent_phases_never_beat_any_phase_alone() {
        let gradsum: Vec<Message> = (0..8).map(|x| msg(x, 0, (x + 1) % 8, 0, 1e6, 0.0)).collect();
        let halo: Vec<Message> = (0..8).map(|x| msg(x, 0, (x + 1) % 8, 0, 4e5, 0.0)).collect();
        let a = sim(8, 1).makespan(&gradsum);
        let b = sim(8, 1).makespan(&halo);
        let joint = sim(8, 1).concurrent_makespan(&[&gradsum, &halo]);
        assert!(joint >= a.max(b) - 1e-15, "joint {joint} vs alone {a}/{b}");
        // Sharing the ring links honestly serializes: both phases cross
        // every +x link, so the joint time is the summed transfer.
        assert!((joint - ((1e6 + 4e5) / 1e9 + 1e-6)).abs() < 1e-12);
    }

    #[test]
    fn appending_a_phase_leaves_the_first_phase_times_unchanged() {
        let gradsum: Vec<Message> = (0..8).map(|x| msg(x, 0, (x + 1) % 8, 0, 1e6, 0.0)).collect();
        let halo: Vec<Message> = (0..8).map(|x| msg(x, 0, (x + 1) % 8, 0, 4e5, 0.0)).collect();
        let alone = sim(8, 1).run(&gradsum);
        let mut joint_sim = sim(8, 1);
        let all: Vec<Message> = gradsum.iter().chain(halo.iter()).copied().collect();
        let joint = joint_sim.run(&all);
        for (a, j) in alone.iter().zip(joint.iter()) {
            assert_eq!(a.arrived_at.to_bits(), j.arrived_at.to_bits());
        }
    }
}
