//! Hierarchical multi-pod topology: placement specs and cross-pod pricing.
//!
//! The paper stops at one 1024-chip pod; its follow-up ("Exploring the
//! Limits of Concurrency in ML Training on Google TPUs", arxiv
//! 2011.03641) spans pod boundaries, where inter-pod links are a fixed
//! factor slower than the intra-pod 2-D torus links. This module is the
//! single entry point for turning a chip count into a placement
//! ([`TopologySpec::place`]) and for pricing gradient summation over a
//! *pod group*: `pods` identical 2-D tori joined by inter-pod links at
//! `inter_pod_ratio` of the torus link bandwidth.
//!
//! Two cross-pod strategies are priced ([`CrossPodStrategy`]):
//!
//! * **Hierarchical** (reduce-then-broadcast): the full 4-phase 2-D
//!   schedule inside each pod, then a bidirectional ring all-reduce of
//!   the per-chip shard across the `pods` pod leaders over the slow
//!   links. Intra-pod phases are identical across pods and overlap
//!   perfectly, so the group price is one pod's price plus the cross
//!   term.
//! * **FlatRing**: one global 1-D ring over every chip in the group,
//!   ignoring the hierarchy. The ring steps are priced event-driven with
//!   per-link bandwidth overrides on the pod-boundary links
//!   ([`super::NetSim::set_link_bw`]), so the slow links honestly
//!   bottleneck every one of the `2*(n-1)` steps.
//!
//! Single-pod reduction is exact by construction: a [`PodSpec`] with
//! `pods == 1` or `inter_pod_ratio == 1.0` [`PodSpec::collapses`] and
//! delegates verbatim to the flat-torus fast path, so every pre-existing
//! single-pod price is bit-identical (pinned by `tests/multipod.rs`).
//!
//! Non-uniform payload schedules route through the guarded entry point
//! ([`pod_group_gradsum_makespan_guarded`]) and are priced by the full
//! event-driven simulation (`fastpath: false`), never by the symmetry
//! shortcut; [`schedule_fingerprint`] gives memoization caches a stable
//! key over the exact payload bit-pattern.

use super::cost::NetParams;
use super::fastpath::{
    payload_uniform, ring_step_makespan, torus2d_gradsum_event_makespan, torus2d_gradsum_makespan,
    torus2d_gradsum_makespan_guarded, GuardedMakespan,
};
use super::sim::{Message, NetSim};
use super::torus::{Coord, Dir, Torus};

/// How gradient summation crosses pod boundaries.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum CrossPodStrategy {
    /// Reduce inside each pod first, then all-reduce the shard across
    /// pods over the slow links (reduce-then-broadcast).
    Hierarchical,
    /// One flat 1-D ring over every chip in the group; pod-boundary
    /// links bottleneck every step.
    FlatRing,
}

impl CrossPodStrategy {
    /// Stable label used in grid names, CLI flags and `SweepRecord`s.
    pub fn label(&self) -> &'static str {
        match self {
            CrossPodStrategy::Hierarchical => "hierarchical",
            CrossPodStrategy::FlatRing => "flat-ring",
        }
    }

    /// Inverse of [`label`](Self::label).
    pub fn parse(s: &str) -> Option<CrossPodStrategy> {
        match s {
            "hierarchical" => Some(CrossPodStrategy::Hierarchical),
            "flat-ring" => Some(CrossPodStrategy::FlatRing),
            _ => None,
        }
    }
}

/// Multi-pod shape of a job: how many pods share the work and how much
/// slower the links between them are. The default is the paper's
/// single-pod world and collapses to the flat 2-D torus everywhere.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PodSpec {
    /// Number of pods in the group (1 = the paper's single-pod setup).
    pub pods: usize,
    /// Inter-pod link bandwidth as a fraction of the intra-pod link
    /// bandwidth, in `(0, 1]`; `1.0` makes the hierarchy invisible.
    pub inter_pod_ratio: f64,
    /// Cross-pod gradient-summation strategy.
    pub strategy: CrossPodStrategy,
}

impl Default for PodSpec {
    fn default() -> PodSpec {
        PodSpec { pods: 1, inter_pod_ratio: 1.0, strategy: CrossPodStrategy::Hierarchical }
    }
}

impl PodSpec {
    pub fn new(pods: usize, inter_pod_ratio: f64) -> PodSpec {
        PodSpec { pods, inter_pod_ratio, ..PodSpec::default() }
    }

    /// The same spec with a different cross-pod strategy.
    pub fn with_strategy(mut self, strategy: CrossPodStrategy) -> PodSpec {
        self.strategy = strategy;
        self
    }

    /// Whether the hierarchy is indistinguishable from a flat torus:
    /// one pod, or inter-pod links exactly as fast as intra-pod links.
    /// Collapsing specs must price bit-identically to the flat model.
    pub fn collapses(&self) -> bool {
        self.pods <= 1 || self.inter_pod_ratio == 1.0
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.pods < 1 {
            return Err("pod count must be at least 1".to_string());
        }
        if !(self.inter_pod_ratio > 0.0 && self.inter_pod_ratio <= 1.0) {
            return Err(format!(
                "inter-pod bandwidth ratio must be in (0, 1], got {}",
                self.inter_pod_ratio
            ));
        }
        Ok(())
    }
}

/// How to turn a chip count into a torus placement — the one entry point
/// behind `Torus::for_chips`, `Torus::for_chips_idle` and the multi-pod
/// group constructor (which are all thin wrappers over [`place`]).
///
/// [`place`]: TopologySpec::place
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TopologySpec {
    /// Exact factorization: every chip used, `ny <= nx`, degenerate
    /// aspect ratios allowed (primes become 1-D rings).
    Exact,
    /// Best rectangular torus of at most `chips` chips with
    /// `nx <= ny * max_aspect`; the remainder idles.
    Capped { max_aspect: usize },
    /// `pods` identical capped tori over an even split of the chips;
    /// chips that fit no pod idle.
    Pods { pods: usize, max_aspect: usize, inter_pod_ratio: f64 },
}

/// A placed topology: the per-pod torus, how many pods repeat it, the
/// inter-pod bandwidth ratio joining them, and the idle remainder.
#[derive(Clone, Copy, Debug)]
pub struct Placement {
    pub pod_torus: Torus,
    pub pods: usize,
    pub inter_pod_ratio: f64,
    pub idle: usize,
}

impl Placement {
    /// Chips actually participating across every pod.
    pub fn used_chips(&self) -> usize {
        self.pod_torus.chips() * self.pods
    }
}

/// Exact factorization (moved verbatim from `Torus::for_chips`): the
/// largest divisor at most `sqrt(chips)` becomes `ny`.
fn exact_factor(chips: usize) -> Torus {
    assert!(chips >= 1, "chip count must be at least 1");
    let mut ny = 1;
    let mut d = 1;
    while d * d <= chips {
        if chips % d == 0 {
            ny = d;
        }
        d += 1;
    }
    Torus::new(chips / ny, ny)
}

/// Aspect-capped factorization with idle remainder (moved verbatim from
/// `Torus::for_chips_idle`).
fn capped_factor(chips: usize, max_aspect: usize) -> (Torus, usize) {
    assert!(chips >= 1, "chip count must be at least 1");
    assert!(max_aspect >= 1);
    for used in (1..=chips).rev() {
        let t = exact_factor(used);
        if t.nx <= t.ny * max_aspect {
            return (t, chips - used);
        }
    }
    (Torus::new(1, 1), chips - 1)
}

impl TopologySpec {
    /// Place `chips` chips under this spec.
    pub fn place(&self, chips: usize) -> Placement {
        match *self {
            TopologySpec::Exact => {
                let t = exact_factor(chips);
                Placement { pod_torus: t, pods: 1, inter_pod_ratio: 1.0, idle: 0 }
            }
            TopologySpec::Capped { max_aspect } => {
                let (t, idle) = capped_factor(chips, max_aspect);
                Placement { pod_torus: t, pods: 1, inter_pod_ratio: 1.0, idle }
            }
            TopologySpec::Pods { pods, max_aspect, inter_pod_ratio } => {
                assert!(pods >= 1, "pod count must be at least 1");
                let per_pod = (chips / pods).max(1);
                let (t, _) = capped_factor(per_pod, max_aspect);
                let used = t.chips() * pods;
                Placement {
                    pod_torus: t,
                    pods,
                    inter_pod_ratio,
                    idle: chips.saturating_sub(used),
                }
            }
        }
    }
}

/// `NetParams` with the link bandwidth scaled down to the inter-pod rate.
fn inter_pod_params(p: &NetParams, ratio: f64) -> NetParams {
    NetParams { link_bw: ratio * p.link_bw, ..*p }
}

/// Cross-pod all-reduce seconds for a per-chip shard of `shard_bytes`:
/// `2*(pods-1)` bidirectional ring steps across the pod leaders over the
/// inter-pod links. Zero when the spec collapses to a single pod.
pub fn cross_pod_ring_seconds(pods: PodSpec, shard_bytes: f64, p: &NetParams) -> f64 {
    if pods.collapses() {
        return 0.0;
    }
    let p_inter = inter_pod_params(p, pods.inter_pod_ratio);
    2.0 * (pods.pods - 1) as f64
        * ring_step_makespan(pods.pods, shard_bytes / pods.pods as f64, &p_inter)
}

/// One bidirectional ring step over the flat multi-pod ring, priced
/// event-driven with the pod-boundary links slowed to the inter-pod
/// rate. `chunk_of(id)` gives the per-chip chunk for this step.
fn flat_ring_step(
    n: usize,
    pod_chips: usize,
    ratio: f64,
    p: &NetParams,
    chunk_of: impl Fn(usize) -> f64,
) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let ring = Torus::new(n, 1);
    let mut sim = NetSim::new(ring, p.link_bw, p.link_latency);
    let slow = ratio * p.link_bw;
    for pod in 0..n.div_ceil(pod_chips) {
        let first = pod * pod_chips;
        let last = (first + pod_chips - 1).min(n - 1);
        // Both directed links crossing the boundary after this pod.
        sim.set_link_bw(Coord { x: last, y: 0 }, Dir::XPlus, slow);
        sim.set_link_bw(Coord { x: (last + 1) % n, y: 0 }, Dir::XMinus, slow);
        // And the boundary before it (wraps to the previous pod's tail).
        sim.set_link_bw(Coord { x: first, y: 0 }, Dir::XMinus, slow);
        sim.set_link_bw(Coord { x: (first + n - 1) % n, y: 0 }, Dir::XPlus, slow);
    }
    let msgs: Vec<Message> = ring
        .coords()
        .flat_map(|c| {
            let half = chunk_of(ring.id(c)) / 2.0;
            [
                Message { src: c, dst: ring.step(c, Dir::XPlus), bytes: half, ready_at: 0.0 },
                Message { src: c, dst: ring.step(c, Dir::XMinus), bytes: half, ready_at: 0.0 },
            ]
        })
        .collect();
    sim.makespan(&msgs)
}

/// Gradient-summation makespan of a pod group under a uniform per-chip
/// payload. Collapsing specs ([`PodSpec::collapses`]) delegate verbatim
/// to the flat 2-D torus price over the *requested* chip count, so the
/// single-pod reduction is bit-identical to the pre-hierarchy model.
pub fn pod_group_gradsum_makespan(
    chips: usize,
    pods: PodSpec,
    max_aspect: usize,
    payload_bytes: f64,
    p: &NetParams,
) -> f64 {
    if pods.collapses() {
        let (torus, _) = capped_factor(chips.max(1), max_aspect);
        return torus2d_gradsum_makespan(torus, payload_bytes, p);
    }
    let placement =
        TopologySpec::Pods { pods: pods.pods, max_aspect, inter_pod_ratio: pods.inter_pod_ratio }
            .place(chips.max(1));
    let t = placement.pod_torus;
    match pods.strategy {
        CrossPodStrategy::Hierarchical => {
            let intra = torus2d_gradsum_makespan(t, payload_bytes, p);
            let shard = payload_bytes / t.chips() as f64;
            intra + cross_pod_ring_seconds(pods, shard, p)
        }
        CrossPodStrategy::FlatRing => {
            let n = placement.used_chips();
            let chunk = payload_bytes / n as f64;
            let step = flat_ring_step(n, t.chips(), pods.inter_pod_ratio, p, |_| chunk);
            2.0 * (n.saturating_sub(1)) as f64 * step
        }
    }
}

/// Guarded multi-pod gradient summation over a per-chip payload
/// schedule (row-major within each pod, pods concatenated). Uniform
/// schedules take the symmetry fast path (and collapsing specs delegate
/// to the flat guarded entry point bit-identically); any non-uniform
/// schedule is priced by the event-driven simulation and reports
/// `fastpath: false`.
pub fn pod_group_gradsum_makespan_guarded(
    chips: usize,
    pods: PodSpec,
    max_aspect: usize,
    payloads: &[f64],
    p: &NetParams,
) -> GuardedMakespan {
    if pods.collapses() {
        let (torus, _) = capped_factor(chips.max(1), max_aspect);
        return torus2d_gradsum_makespan_guarded(torus, payloads, p);
    }
    let placement =
        TopologySpec::Pods { pods: pods.pods, max_aspect, inter_pod_ratio: pods.inter_pod_ratio }
            .place(chips.max(1));
    let t = placement.pod_torus;
    assert_eq!(payloads.len(), placement.used_chips(), "one payload per participating chip");
    if payload_uniform(payloads) {
        let payload = payloads.first().copied().unwrap_or(0.0);
        return GuardedMakespan {
            seconds: pod_group_gradsum_makespan(chips, pods, max_aspect, payload, p),
            fastpath: true,
        };
    }
    let seconds = match pods.strategy {
        CrossPodStrategy::Hierarchical => {
            // Pods no longer mirror each other: price every pod's event
            // schedule and take the straggler.
            let intra = payloads
                .chunks(t.chips())
                .map(|pod| torus2d_gradsum_event_makespan(t, pod, p))
                .fold(0.0, f64::max);
            // The cross-pod ring ships the heaviest chip's shard.
            let heaviest = payloads.iter().cloned().fold(0.0, f64::max);
            let shard = heaviest / t.chips() as f64;
            intra + cross_pod_ring_seconds(pods, shard, p)
        }
        CrossPodStrategy::FlatRing => {
            let n = placement.used_chips();
            let step = flat_ring_step(n, t.chips(), pods.inter_pod_ratio, p, |id| {
                payloads[id] / n as f64
            });
            2.0 * (n.saturating_sub(1)) as f64 * step
        }
    };
    GuardedMakespan { seconds, fastpath: false }
}

/// Stable 64-bit fingerprint of a payload schedule (FNV-1a over the
/// exact f64 bit patterns) — the memoization-key component that makes
/// two different schedules cache separately while staying deterministic
/// across runs and platforms.
pub fn schedule_fingerprint(payloads: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for payload in payloads {
        for byte in payload.to_bits().to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_spec_matches_for_chips_wrapper() {
        for chips in 1..=200 {
            let placed = TopologySpec::Exact.place(chips);
            let t = Torus::for_chips(chips);
            assert_eq!((placed.pod_torus.nx, placed.pod_torus.ny), (t.nx, t.ny));
            assert_eq!(placed.pods, 1);
            assert_eq!(placed.idle, 0);
        }
    }

    #[test]
    fn capped_spec_matches_for_chips_idle_wrapper() {
        for chips in 1..=200 {
            let placed = TopologySpec::Capped { max_aspect: 4 }.place(chips);
            let (t, idle) = Torus::for_chips_idle(chips, 4);
            assert_eq!((placed.pod_torus.nx, placed.pod_torus.ny), (t.nx, t.ny));
            assert_eq!(placed.idle, idle);
        }
    }

    #[test]
    fn pod_group_places_identical_tori() {
        let placed =
            TopologySpec::Pods { pods: 2, max_aspect: 4, inter_pod_ratio: 0.25 }.place(2048);
        assert_eq!((placed.pod_torus.nx, placed.pod_torus.ny), (32, 32));
        assert_eq!(placed.pods, 2);
        assert_eq!(placed.used_chips(), 2048);
        assert_eq!(placed.idle, 0);
        // Ragged counts drop the chips no pod can hold.
        let ragged =
            TopologySpec::Pods { pods: 3, max_aspect: 4, inter_pod_ratio: 0.5 }.place(100);
        assert_eq!(ragged.used_chips() + ragged.idle, 100);
    }

    #[test]
    fn collapsing_specs_price_bit_identically_to_the_flat_torus() {
        let p = NetParams::default();
        for chips in [16usize, 64, 256, 1024] {
            let flat = torus2d_gradsum_makespan(Torus::for_chips_idle(chips, 4).0, 3.3e7, &p);
            for pods in [
                PodSpec::default(),
                PodSpec::new(1, 0.25),
                PodSpec::new(4, 1.0),
                PodSpec { strategy: CrossPodStrategy::FlatRing, ..PodSpec::new(1, 1.0) },
            ] {
                let group = pod_group_gradsum_makespan(chips, pods, 4, 3.3e7, &p);
                assert_eq!(group.to_bits(), flat.to_bits(), "{chips} chips, {pods:?}");
            }
        }
    }

    #[test]
    fn slower_inter_pod_links_cost_more() {
        let p = NetParams::default();
        let fast = pod_group_gradsum_makespan(512, PodSpec::new(2, 0.5), 4, 1e8, &p);
        let slow = pod_group_gradsum_makespan(512, PodSpec::new(2, 0.1), 4, 1e8, &p);
        let collapsed = pod_group_gradsum_makespan(512, PodSpec::new(2, 1.0), 4, 1e8, &p);
        assert!(slow > fast, "slow {slow} vs fast {fast}");
        assert!(fast > 0.0 && collapsed > 0.0);
    }

    #[test]
    fn hierarchical_beats_flat_ring_across_slow_links() {
        let p = NetParams::default();
        let hier = pod_group_gradsum_makespan(128, PodSpec::new(2, 0.25), 4, 1e8, &p);
        let flat = pod_group_gradsum_makespan(
            128,
            PodSpec { strategy: CrossPodStrategy::FlatRing, ..PodSpec::new(2, 0.25) },
            4,
            1e8,
            &p,
        );
        assert!(
            flat > hier,
            "flat ring over slow boundaries ({flat}) must lose to hierarchical ({hier})"
        );
    }

    #[test]
    fn non_uniform_schedules_route_to_the_event_engine() {
        let p = NetParams::default();
        for pods in [PodSpec::new(2, 0.25), PodSpec::default()] {
            let placed = TopologySpec::Pods {
                pods: pods.pods,
                max_aspect: 4,
                inter_pod_ratio: pods.inter_pod_ratio,
            }
            .place(32);
            let n = if pods.collapses() {
                Torus::for_chips_idle(32, 4).0.chips()
            } else {
                placed.used_chips()
            };
            let mut payloads = vec![1e6; n];
            payloads[3] = 9e6;
            let g = pod_group_gradsum_makespan_guarded(32, pods, 4, &payloads, &p);
            assert!(!g.fastpath, "{pods:?}");
            let base = vec![1e6; n];
            let uniform = pod_group_gradsum_makespan_guarded(32, pods, 4, &base, &p);
            assert!(uniform.fastpath);
            assert!(g.seconds >= uniform.seconds - 1e-12);
        }
    }

    #[test]
    fn strategy_labels_round_trip() {
        for s in [CrossPodStrategy::Hierarchical, CrossPodStrategy::FlatRing] {
            assert_eq!(CrossPodStrategy::parse(s.label()), Some(s));
        }
        assert_eq!(CrossPodStrategy::parse("diagonal"), None);
    }

    #[test]
    fn pod_spec_validation() {
        assert!(PodSpec::default().validate().is_ok());
        assert!(PodSpec::new(4, 0.25).validate().is_ok());
        assert!(PodSpec::new(0, 0.5).validate().is_err());
        assert!(PodSpec::new(2, 0.0).validate().is_err());
        assert!(PodSpec::new(2, 1.5).validate().is_err());
        assert!(PodSpec::new(2, f64::NAN).validate().is_err());
    }

    #[test]
    fn schedule_fingerprints_distinguish_schedules() {
        let a = schedule_fingerprint(&[1e6, 1e6, 1e6]);
        let b = schedule_fingerprint(&[1e6, 2e6, 1e6]);
        let c = schedule_fingerprint(&[1e6, 1e6, 1e6]);
        assert_eq!(a, c);
        assert_ne!(a, b);
        assert_ne!(schedule_fingerprint(&[]), schedule_fingerprint(&[0.0]));
    }
}
