//! 2-D torus topology (paper Fig. 2: "1024 TPU-v3 chips ... interconnected
//! by a custom high throughput 2-D torus network").
//!
//! Nodes are chips, addressed by (x, y). Each chip has four links (+x, -x,
//! +y, -y) that wrap around; a TPU-v3 pod is a 32x32 torus. Routing is
//! dimension-ordered (X then Y) with shortest wrap direction per dimension,
//! matching how the XLA collectives schedule neighbor exchanges.

/// Chip coordinate on the torus.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct Coord {
    pub x: usize,
    pub y: usize,
}

/// One of the four torus directions.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Dir {
    XPlus,
    XMinus,
    YPlus,
    YMinus,
}

/// A directed link: the `dir`-facing port of `from`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct Link {
    pub from: Coord,
    pub dir: Dir,
}

/// 2-D torus of `nx` x `ny` chips.
#[derive(Clone, Copy, Debug)]
pub struct Torus {
    pub nx: usize,
    pub ny: usize,
}

impl Torus {
    pub fn new(nx: usize, ny: usize) -> Torus {
        assert!(nx >= 1 && ny >= 1);
        Torus { nx, ny }
    }

    /// Square-ish torus for a given chip count: the exact factorization
    /// `nx * ny == chips` with `ny` the largest divisor at most √chips
    /// (1024 → 32x32, 128 → 16x8, 12 → 4x3, primes → 1-D ring).
    ///
    /// Thin wrapper over [`TopologySpec::Exact`](super::TopologySpec) —
    /// the placement logic lives in `netsim::topology`.
    pub fn for_chips(chips: usize) -> Torus {
        super::topology::TopologySpec::Exact.place(chips).pod_torus
    }

    /// Best rectangular torus of *at most* `chips` chips with aspect ratio
    /// `nx/ny <= max_aspect`, plus the explicit idle remainder. Ragged chip
    /// counts whose exact factorization would degenerate (97 → 97x1) drop a
    /// few chips instead (97 → 12x8 with 1 idle); chip counts that factor
    /// well — every power of two included — use all chips with zero idle.
    ///
    /// Thin wrapper over [`TopologySpec::Capped`](super::TopologySpec).
    pub fn for_chips_idle(chips: usize, max_aspect: usize) -> (Torus, usize) {
        let placed = super::topology::TopologySpec::Capped { max_aspect }.place(chips);
        (placed.pod_torus, placed.idle)
    }

    pub fn chips(&self) -> usize {
        self.nx * self.ny
    }

    pub fn contains(&self, c: Coord) -> bool {
        c.x < self.nx && c.y < self.ny
    }

    /// Neighbor in a direction (with wraparound).
    pub fn step(&self, c: Coord, dir: Dir) -> Coord {
        match dir {
            Dir::XPlus => Coord { x: (c.x + 1) % self.nx, y: c.y },
            Dir::XMinus => Coord { x: (c.x + self.nx - 1) % self.nx, y: c.y },
            Dir::YPlus => Coord { x: c.x, y: (c.y + 1) % self.ny },
            Dir::YMinus => Coord { x: c.x, y: (c.y + self.ny - 1) % self.ny },
        }
    }

    /// Shortest signed offset from a to b along a ring of length n.
    fn ring_delta(n: usize, a: usize, b: usize) -> isize {
        let fwd = (b + n - a) % n;
        if fwd <= n / 2 {
            fwd as isize
        } else {
            fwd as isize - n as isize
        }
    }

    /// Minimal hop count between two chips.
    pub fn hops(&self, a: Coord, b: Coord) -> usize {
        Self::ring_delta(self.nx, a.x, b.x).unsigned_abs()
            + Self::ring_delta(self.ny, a.y, b.y).unsigned_abs()
    }

    /// Dimension-ordered (X-then-Y) shortest route; returns the link sequence.
    pub fn route(&self, a: Coord, b: Coord) -> Vec<Link> {
        let mut links = Vec::new();
        let mut cur = a;
        let dx = Self::ring_delta(self.nx, a.x, b.x);
        let dir = if dx >= 0 { Dir::XPlus } else { Dir::XMinus };
        for _ in 0..dx.unsigned_abs() {
            links.push(Link { from: cur, dir });
            cur = self.step(cur, dir);
        }
        let dy = Self::ring_delta(self.ny, a.y, b.y);
        let dir = if dy >= 0 { Dir::YPlus } else { Dir::YMinus };
        for _ in 0..dy.unsigned_abs() {
            links.push(Link { from: cur, dir });
            cur = self.step(cur, dir);
        }
        links
    }

    /// All chips in row-major order.
    pub fn coords(&self) -> impl Iterator<Item = Coord> + '_ {
        (0..self.ny).flat_map(move |y| (0..self.nx).map(move |x| Coord { x, y }))
    }

    /// Row-major linear id.
    pub fn id(&self, c: Coord) -> usize {
        c.y * self.nx + c.x
    }

    pub fn coord(&self, id: usize) -> Coord {
        Coord { x: id % self.nx, y: id / self.nx }
    }

    /// Network diameter (max shortest-path hops).
    pub fn diameter(&self) -> usize {
        self.nx / 2 + self.ny / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pod_is_32x32() {
        let t = Torus::for_chips(1024);
        assert_eq!((t.nx, t.ny), (32, 32));
        assert_eq!(t.chips(), 1024);
    }

    #[test]
    fn non_square_power_of_two() {
        let t = Torus::for_chips(128);
        assert_eq!((t.nx, t.ny), (16, 8));
    }

    #[test]
    fn non_power_of_two_factors_exactly() {
        for chips in 1..=200 {
            let t = Torus::for_chips(chips);
            assert_eq!(t.chips(), chips, "for_chips({chips}) must use every chip");
            assert!(t.ny <= t.nx, "ny <= nx convention");
            assert!(t.ny * t.ny <= chips, "ny is at most sqrt(chips)");
        }
        assert_eq!((Torus::for_chips(12).nx, Torus::for_chips(12).ny), (4, 3));
        assert_eq!((Torus::for_chips(96).nx, Torus::for_chips(96).ny), (12, 8));
        assert_eq!((Torus::for_chips(7).nx, Torus::for_chips(7).ny), (7, 1));
    }

    #[test]
    fn idle_remainder_caps_aspect_ratio() {
        // Primes drop chips to stay rectangular; good factorizations keep all.
        let (t, idle) = Torus::for_chips_idle(97, 4);
        assert_eq!((t.nx, t.ny, idle), (12, 8, 1));
        for chips in [1usize, 2, 3, 6, 12, 96, 128, 1024] {
            let (t, idle) = Torus::for_chips_idle(chips, 4);
            assert_eq!(idle, 0, "{chips} chips factor within aspect 4");
            assert_eq!(t.chips(), chips);
            assert!(t.nx <= t.ny * 4);
        }
    }

    #[test]
    fn wraparound_steps() {
        let t = Torus::new(4, 4);
        assert_eq!(t.step(Coord { x: 3, y: 0 }, Dir::XPlus), Coord { x: 0, y: 0 });
        assert_eq!(t.step(Coord { x: 0, y: 0 }, Dir::YMinus), Coord { x: 0, y: 3 });
    }

    #[test]
    fn hops_use_shortest_wrap() {
        let t = Torus::new(8, 8);
        // 0 → 7 is 1 hop backwards, not 7 forwards.
        assert_eq!(t.hops(Coord { x: 0, y: 0 }, Coord { x: 7, y: 0 }), 1);
        assert_eq!(t.hops(Coord { x: 0, y: 0 }, Coord { x: 4, y: 4 }), 8);
    }

    #[test]
    fn route_matches_hops_and_reaches_target() {
        let t = Torus::new(8, 4);
        for a in t.coords() {
            for b in t.coords() {
                let r = t.route(a, b);
                assert_eq!(r.len(), t.hops(a, b), "{a:?}->{b:?}");
                let mut cur = a;
                for l in &r {
                    assert_eq!(l.from, cur);
                    cur = t.step(cur, l.dir);
                }
                assert_eq!(cur, b);
            }
        }
    }

    #[test]
    fn diameter_of_pod() {
        assert_eq!(Torus::for_chips(1024).diameter(), 32);
    }

    #[test]
    fn id_coord_round_trip() {
        let t = Torus::new(8, 4);
        for (i, c) in t.coords().enumerate() {
            assert_eq!(t.id(c), i);
            assert_eq!(t.coord(i), c);
        }
    }
}
