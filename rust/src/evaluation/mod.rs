//! Distributed evaluation (paper §2 "Distribute evaluation computation"):
//!
//! > "We designed a new train and evaluation tight loop that is executed on
//! > the TPU accelerators. Both train and evaluation are distributed on all
//! > the TPU-v3 pod accelerator cores. ... The evaluation dataset is padded
//! > with zeros when the evaluation examples is not a multiple of the
//! > evaluation batch size. Only output tensors from the TPU cores that
//! > have real examples is considered while computing the top-1 accuracy
//! > metric."
//!
//! This module owns the sharding/padding/masking arithmetic and the metric
//! aggregation; the actual per-batch metric computation is a closure (the
//! trainer passes the AOT eval-step executable; unit tests pass plain
//! functions).

use crate::collectives::all_reduce_scalars;
use crate::fabric::Endpoint;

/// Shard layout of a padded evaluation pass.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalSharding {
    pub eval_examples: usize,
    pub cores: usize,
    pub per_core_batch: usize,
}

/// One core-batch worth of eval work: global example indices + mask.
#[derive(Clone, Debug, PartialEq)]
pub struct EvalChunk {
    /// Global example index per slot (padding slots repeat index 0).
    pub indices: Vec<usize>,
    /// 1.0 for real examples, 0.0 for padding.
    pub mask: Vec<f32>,
}

impl EvalSharding {
    pub fn new(eval_examples: usize, cores: usize, per_core_batch: usize) -> EvalSharding {
        assert!(cores >= 1 && per_core_batch >= 1);
        EvalSharding { eval_examples, cores, per_core_batch }
    }

    /// Examples consumed per synchronous eval step across all cores.
    pub fn stride(&self) -> usize {
        self.cores * self.per_core_batch
    }

    /// Number of synchronous eval steps (padding fills the last one).
    pub fn steps(&self) -> usize {
        self.eval_examples.div_ceil(self.stride())
    }

    /// Total padded slots (paper: "padded with zeros when the evaluation
    /// examples is not a multiple of the evaluation batch size").
    pub fn padded_examples(&self) -> usize {
        self.steps() * self.stride()
    }

    /// Padded examples each core evaluates — what the cost layer
    /// (`costs::EvalPhase`) charges per core, padding included.
    pub fn padded_per_core(&self) -> usize {
        self.steps() * self.per_core_batch
    }

    /// The chunk core `core` evaluates at eval step `step`.
    pub fn chunk(&self, core: usize, step: usize) -> EvalChunk {
        assert!(core < self.cores && step < self.steps());
        let base = step * self.stride() + core * self.per_core_batch;
        let mut indices = Vec::with_capacity(self.per_core_batch);
        let mut mask = Vec::with_capacity(self.per_core_batch);
        for i in 0..self.per_core_batch {
            let g = base + i;
            if g < self.eval_examples {
                indices.push(g);
                mask.push(1.0);
            } else {
                indices.push(0); // zero-padding slot
                mask.push(0.0);
            }
        }
        EvalChunk { indices, mask }
    }
}

/// Aggregated eval metrics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalResult {
    pub loss: f64,
    pub accuracy: f64,
    pub count: f64,
}

/// Run one distributed evaluation pass. `eval_batch` maps this core's
/// chunk to local `(loss_sum, correct, count)`; sums are all-reduced across
/// `group` so every core returns the same global metrics.
pub fn distributed_eval<F>(
    ep: &mut Endpoint,
    group: &[usize],
    sharding: &EvalSharding,
    mut eval_batch: F,
) -> EvalResult
where
    F: FnMut(&EvalChunk) -> (f32, f32, f32),
{
    let my_pos = group.iter().position(|&r| r == ep.rank).expect("rank not in group");
    let mut sums = [0.0f32; 3];
    for step in 0..sharding.steps() {
        let chunk = sharding.chunk(my_pos, step);
        let (l, c, n) = eval_batch(&chunk);
        sums[0] += l;
        sums[1] += c;
        sums[2] += n;
    }
    all_reduce_scalars(ep, group, &mut sums);
    let count = sums[2] as f64;
    EvalResult {
        loss: sums[0] as f64 / count.max(1.0),
        accuracy: sums[1] as f64 / count.max(1.0),
        count,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::run_spmd;

    #[test]
    fn sharding_covers_every_example_once() {
        let s = EvalSharding::new(103, 4, 8);
        assert_eq!(s.stride(), 32);
        assert_eq!(s.steps(), 4);
        assert_eq!(s.padded_examples(), 128);
        let mut seen = vec![0u32; 103];
        let mut pad = 0;
        for step in 0..s.steps() {
            for core in 0..4 {
                let c = s.chunk(core, step);
                for (i, &g) in c.indices.iter().enumerate() {
                    if c.mask[i] == 1.0 {
                        seen[g] += 1;
                    } else {
                        pad += 1;
                    }
                }
            }
        }
        assert!(seen.iter().all(|&n| n == 1));
        assert_eq!(pad, 128 - 103);
    }

    #[test]
    fn exact_multiple_needs_no_padding() {
        let s = EvalSharding::new(64, 4, 8);
        assert_eq!(s.padded_examples(), 64);
        assert_eq!(s.padded_per_core(), 16);
        let c = s.chunk(3, 1);
        assert!(c.mask.iter().all(|&m| m == 1.0));
    }

    #[test]
    fn padded_per_core_covers_the_dataset() {
        let s = EvalSharding::new(50000, 2048, 1);
        assert_eq!(s.padded_per_core(), 25);
        assert!(s.padded_per_core() * s.cores >= s.eval_examples);
    }

    #[test]
    fn distributed_eval_matches_serial() {
        // Synthetic metric: example g has loss g, "correct" iff g % 3 == 0.
        let n = 50;
        let world = 4;
        let serial_loss: f32 = (0..n).map(|g| g as f32).sum();
        let serial_correct = (0..n).filter(|g| g % 3 == 0).count() as f32;

        let out = run_spmd(world, |ep| {
            let group: Vec<usize> = (0..world).collect();
            let s = EvalSharding::new(n, world, 4);
            distributed_eval(ep, &group, &s, |chunk| {
                let mut l = 0.0;
                let mut c = 0.0;
                let mut cnt = 0.0;
                for (i, &g) in chunk.indices.iter().enumerate() {
                    if chunk.mask[i] == 1.0 {
                        l += g as f32;
                        c += if g % 3 == 0 { 1.0 } else { 0.0 };
                        cnt += 1.0;
                    }
                }
                (l, c, cnt)
            })
        });
        for r in 0..world {
            assert_eq!(out[r].count, n as f64);
            assert!((out[r].loss - serial_loss as f64 / n as f64).abs() < 1e-3);
            assert!(
                (out[r].accuracy - serial_correct as f64 / n as f64).abs() < 1e-6,
                "rank {r}"
            );
        }
    }

    #[test]
    fn padding_does_not_perturb_metrics() {
        // Same dataset, different core counts → identical metrics even
        // though padding differs.
        let n = 37;
        let metric = |chunk: &EvalChunk| {
            let mut l = 0.0;
            let mut c = 0.0;
            let mut cnt = 0.0;
            for (i, &g) in chunk.indices.iter().enumerate() {
                // Deliberately return garbage for padded slots — the mask
                // must exclude it.
                if chunk.mask[i] == 1.0 {
                    l += (g * g) as f32;
                    c += (g % 2) as f32;
                    cnt += 1.0;
                }
            }
            (l, c, cnt)
        };
        let r2 = run_spmd(2, |ep| {
            distributed_eval(ep, &[0, 1], &EvalSharding::new(n, 2, 4), metric)
        });
        let r8 = run_spmd(8, |ep| {
            let group: Vec<usize> = (0..8).collect();
            distributed_eval(ep, &group, &EvalSharding::new(n, 8, 4), metric)
        });
        assert_eq!(r2[0], r8[0]);
    }
}
