"""L1 correctness: Pallas kernels vs pure-jnp oracles (kernels/ref.py),
hypothesis-swept over shapes, sizes and hyper-parameters.

This is the core correctness signal for the kernel layer: the Rust optimizer
implementations are separately bit-compared against HLO lowered from these
same kernels, so kernel==ref here closes the Rust==Pallas==ref triangle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adam, attention, lars, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# LARS
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=3 * lars.BLK + 17),
    lr=st.floats(1e-4, 10.0),
    eta=st.floats(1e-4, 0.1),
    beta=st.floats(0.0, 1e-2),
    momentum=st.floats(0.0, 0.99),
    scaled=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_lars_matches_ref(n, lr, eta, beta, momentum, scaled, seed):
    w, g, v = (_rand(seed + i, n) for i in range(3))
    hp = jnp.array([lr, eta, beta, momentum], jnp.float32)
    w1, v1 = lars.lars_update(w, g, v, hp, scaled=scaled)
    fn = ref.lars_scaled_ref if scaled else ref.lars_unscaled_ref
    w2, v2 = fn(w, g, v, lr, eta, beta, momentum)
    np.testing.assert_allclose(w1, w2, rtol=3e-5, atol=1e-5)
    np.testing.assert_allclose(v1, v2, rtol=3e-5, atol=1e-5)


def test_lars_variants_differ():
    """Scaled vs unscaled momentum must actually diverge (Figures 5 vs 6) —
    they are identical only in the first step from v=0 when lr*lam == lam."""
    n = 4096
    w, g = _rand(0, n), _rand(1, n)
    v = jnp.abs(_rand(2, n))
    hp = jnp.array([0.5, 0.01, 1e-4, 0.9], jnp.float32)
    ws, _ = lars.lars_update(w, g, v, hp, scaled=True)
    wu, _ = lars.lars_update(w, g, v, hp, scaled=False)
    assert not np.allclose(ws, wu)


def test_lars_padding_is_neutral():
    """Auto-padding must not perturb norms: padded result == exact-size
    result on the unpadded prefix."""
    n = lars.BLK + 123
    w, g, v = (_rand(i, n) for i in range(3))
    hp = jnp.array([0.1, 0.01, 1e-4, 0.9], jnp.float32)
    w1, v1 = lars.lars_update(w, g, v, hp, scaled=False)
    w2, v2 = ref.lars_unscaled_ref(w, g, v, 0.1, 0.01, 1e-4, 0.9)
    np.testing.assert_allclose(w1, w2, rtol=3e-5, atol=1e-5)


def test_lars_norms_blocked_reduction():
    n = 4 * lars.BLK
    w, g = _rand(0, n), _rand(1, n)
    norms = lars.lars_norms(w, g)
    np.testing.assert_allclose(norms[0], jnp.sum(w * w), rtol=1e-5)
    np.testing.assert_allclose(norms[1], jnp.sum(g * g), rtol=1e-5)


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=2 * adam.BLK + 5),
    lr=st.floats(1e-5, 1e-1),
    beta1=st.floats(0.5, 0.999),
    beta2=st.floats(0.9, 0.9999),
    step=st.integers(1, 10000),
    seed=st.integers(0, 2**16),
)
def test_adam_matches_ref(n, lr, beta1, beta2, step, seed):
    w, g = _rand(seed, n), _rand(seed + 1, n)
    m = _rand(seed + 2, n) * 0.1
    v = jnp.abs(_rand(seed + 3, n)) * 0.01
    hp = jnp.array([lr, beta1, beta2, 1e-8, float(step)], jnp.float32)
    out = adam.adam_update(w, g, m, v, hp)
    exp = ref.adam_ref(w, g, m, v, step, lr, beta1, beta2, 1e-8)
    for got, want in zip(out, exp):
        np.testing.assert_allclose(got, want, rtol=5e-5, atol=1e-5)


def test_adam_moments_accumulate_across_steps():
    """Chained kernel steps must track the oracle over a short trajectory."""
    n = 1000
    w, m, v = _rand(0, n), jnp.zeros(n), jnp.zeros(n)
    w2, m2, v2 = w, m, v
    for step in range(1, 6):
        g = _rand(10 + step, n)
        hp = jnp.array([1e-2, 0.9, 0.999, 1e-8, float(step)], jnp.float32)
        w, m, v = adam.adam_update(w, g, m, v, hp)
        w2, m2, v2 = ref.adam_ref(w2, g, m2, v2, step, 1e-2)
    np.testing.assert_allclose(w, w2, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s=st.sampled_from([4, 16, 33, 64]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(b, h, s, d, seed):
    q, k, v = (_rand(seed + i, b, h, s, d) for i in range(3))
    o = attention.attention(q, k, v)
    exp = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(o, exp, rtol=2e-4, atol=1e-4)


def test_attention_is_causal():
    """Future positions must not leak: perturbing position j>i leaves row i
    unchanged."""
    q, k, v = (_rand(i, 1, 1, 8, 4) for i in range(3))
    o1 = attention.attention(q, k, v)
    k2 = k.at[0, 0, 7].set(100.0)
    v2 = v.at[0, 0, 7].set(-100.0)
    o2 = attention.attention(q, k2, v2)
    np.testing.assert_allclose(o1[0, 0, :7], o2[0, 0, :7], rtol=1e-5,
                               atol=1e-6)
    assert not np.allclose(o1[0, 0, 7], o2[0, 0, 7])


def test_attention_grad_matches_ref():
    """custom_vjp backward kernel vs autodiff through the oracle."""
    q, k, v = (_rand(i + 20, 2, 2, 16, 8) for i in range(3))
    t = _rand(99, 2, 2, 16, 8)

    def loss_kernel(q, k, v):
        return jnp.sum((attention.attention(q, k, v) - t) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum((ref.attention_ref(q, k, v) - t) ** 2)

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)


def test_attention_bf16_inputs():
    """Paper mixed-precision rule: bf16 operands, f32 softmax — kernel must
    accept bf16 and stay close to the f32 oracle."""
    q, k, v = (_rand(i + 40, 1, 2, 32, 16) for i in range(3))
    o = attention.attention(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                            v.astype(jnp.bfloat16))
    exp = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(o, exp, rtol=2e-2, atol=2e-2)


def test_attention_rows_sum_preserved():
    """With v = ones, attention output must be exactly ones (softmax rows
    sum to 1) — a property the blocked kernel must preserve."""
    q, k = _rand(0, 2, 2, 16, 8), _rand(1, 2, 2, 16, 8)
    v = jnp.ones((2, 2, 16, 8), jnp.float32)
    o = attention.attention(q, k, v)
    np.testing.assert_allclose(o, np.ones_like(o), rtol=1e-5, atol=1e-5)
