"""L2 model checks: shapes, trainability, eval-mask semantics (the
distributed-eval padding contract the Rust evaluator relies on), and the
mixed-precision rule.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import cnn, model
from compile.configs import CNN_PRESETS, TRANSFORMER_PRESETS

jax.config.update("jax_platform_name", "cpu")

TINY = TRANSFORMER_PRESETS["tiny"]
MINI = CNN_PRESETS["mini"]


@pytest.fixture(scope="module")
def tparams():
    return model.init_params(TINY, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def cparams():
    return cnn.init_params(MINI, jax.random.PRNGKey(0))


def _batch(key, cfg):
    tokens = jax.random.randint(key, (cfg.batch_per_core, cfg.seq), 0,
                                cfg.vocab)
    targets = jnp.roll(tokens, -1, axis=1)
    return tokens, targets


def test_transformer_shapes(tparams):
    tokens, _ = _batch(jax.random.PRNGKey(1), TINY)
    logits = model.forward(TINY, tparams, tokens)
    assert logits.shape == (TINY.batch_per_core, TINY.seq, TINY.vocab)
    assert logits.dtype == jnp.float32


def test_param_spec_matches_init(tparams):
    spec = model.param_spec(TINY)
    assert len(spec) == len(tparams)
    for (name, shape), p in zip(spec, tparams):
        assert p.shape == shape, name


def test_train_step_grads_cover_every_param(tparams):
    step = model.make_train_step(TINY)
    tokens, targets = _batch(jax.random.PRNGKey(2), TINY)
    out = step(*tparams, tokens, targets)
    loss, grads = out[0], out[1:]
    assert loss.shape == ()
    assert len(grads) == len(tparams)
    # Every parameter must receive signal (no dead tensors in the graph).
    for (name, _), g in zip(model.param_spec(TINY), grads):
        assert float(jnp.sum(jnp.abs(g))) > 0.0, f"zero grad for {name}"


def test_transformer_loss_decreases(tparams):
    """A few plain-SGD steps on a fixed batch must reduce the loss — the
    minimal trainability proof before the Rust trainer takes over."""
    step = jax.jit(model.make_train_step(TINY))
    tokens, targets = _batch(jax.random.PRNGKey(3), TINY)
    params = list(tparams)
    losses = []
    for _ in range(8):
        out = step(*params, tokens, targets)
        losses.append(float(out[0]))
        params = [p - 0.1 * g for p, g in zip(params, out[1:])]
    assert losses[-1] < losses[0] * 0.9, losses


def test_eval_mask_excludes_padding(tparams):
    """Zero-padded eval examples (paper §2) must not move the metrics: a
    batch with k masked-in rows must give identical sums regardless of what
    garbage sits in the masked-out rows."""
    eval_step = model.make_eval_step(TINY)
    tokens, targets = _batch(jax.random.PRNGKey(4), TINY)
    mask = jnp.array([1.0] * 3 + [0.0] * (TINY.batch_per_core - 3))
    out1 = eval_step(*tparams, tokens, targets, mask)
    # Trash the masked-out rows.
    tokens2 = tokens.at[3:].set(0)
    targets2 = targets.at[3:].set(0)
    out2 = eval_step(*tparams, tokens2, targets2, mask)
    for a, b in zip(out1, out2):
        np.testing.assert_allclose(a, b, rtol=1e-6)
    assert float(out1[2]) == 3 * TINY.seq  # count = masked-in tokens


def test_eval_all_masked_out_gives_zero(tparams):
    eval_step = model.make_eval_step(TINY)
    tokens, targets = _batch(jax.random.PRNGKey(5), TINY)
    out = eval_step(*tparams, tokens, targets,
                    jnp.zeros(TINY.batch_per_core))
    assert all(float(x) == 0.0 for x in out)


def test_cnn_shapes_and_grads(cparams):
    step = cnn.make_train_step(MINI)
    key = jax.random.PRNGKey(6)
    images = jax.random.normal(key, (MINI.batch_per_core, MINI.image,
                                     MINI.image, 3))
    labels = jax.random.randint(key, (MINI.batch_per_core,), 0, MINI.classes)
    out = step(*cparams, images, labels)
    assert out[0].shape == ()
    assert len(out) - 1 == len(cparams)
    for (name, _), g in zip(cnn.param_spec(MINI), out[1:]):
        assert float(jnp.sum(jnp.abs(g))) > 0.0, f"zero grad for {name}"


def test_cnn_loss_decreases(cparams):
    step = jax.jit(cnn.make_train_step(MINI))
    key = jax.random.PRNGKey(7)
    images = jax.random.normal(key, (MINI.batch_per_core, MINI.image,
                                     MINI.image, 3))
    labels = jax.random.randint(key, (MINI.batch_per_core,), 0, MINI.classes)
    params = list(cparams)
    losses = []
    for _ in range(10):
        out = step(*params, images, labels)
        losses.append(float(out[0]))
        params = [p - 0.05 * g for p, g in zip(params, out[1:])]
    assert losses[-1] < losses[0] * 0.8, losses


def test_mixed_precision_close_to_f32(tparams):
    """bf16-matmul loss must track the f32 loss (paper: 'minimal or no loss
    in model accuracy')."""
    import dataclasses
    cfg32 = dataclasses.replace(TINY, mixed_bf16=False)
    tokens, targets = _batch(jax.random.PRNGKey(8), TINY)
    l16 = model.loss_fn(TINY, tparams, tokens, targets)
    l32 = model.loss_fn(cfg32, tparams, tokens, targets)
    np.testing.assert_allclose(l16, l32, rtol=2e-2)
