"""GNMT LSTM optimization (paper §3): the hoisted-input-projection
formulation must be mathematically equivalent to the traditional cell, for
the forward pass AND the gradients (the paper applies the same hoisting to
the backward path).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import lstm, ref

jax.config.update("jax_platform_name", "cpu")


def _setup(seed, t, b, i, h):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    xs = jax.random.normal(ks[0], (t, b, i))
    h0 = jax.random.normal(ks[1], (b, h)) * 0.1
    c0 = jax.random.normal(ks[2], (b, h)) * 0.1
    w_x = jax.random.normal(ks[3], (i, 4 * h)) * 0.1
    w_h = jax.random.normal(ks[4], (h, 4 * h)) * 0.1
    b_ = jnp.zeros((4 * h,))
    return xs, h0, c0, w_x, w_h, b_


@settings(max_examples=8, deadline=None)
@given(
    t=st.integers(1, 6),
    b=st.sampled_from([8, 16]),   # kernel BATCH_TILE multiples
    i=st.sampled_from([4, 16, 32]),
    h=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**16),
)
def test_hoisted_kernel_equals_traditional(t, b, i, h, seed):
    xs, h0, c0, w_x, w_h, b_ = _setup(seed, t, b, i, h)
    hs_kernel = lstm.lstm_layer_hoisted(xs, h0, c0, w_x, w_h, b_)
    hs_ref = ref.lstm_unrolled_ref(xs, h0, c0, w_x, w_h, b_)
    np.testing.assert_allclose(hs_kernel, hs_ref, rtol=2e-4, atol=1e-4)


def test_hoisted_ref_equals_traditional_ref():
    """Pure-jnp sanity: the algebraic rewrite alone (no kernel) is exact."""
    xs, h0, c0, w_x, w_h, b_ = _setup(7, 9, 4, 12, 24)
    hs1 = ref.lstm_hoisted_pipeline_ref(xs, h0, c0, w_x, w_h, b_)
    hs2 = ref.lstm_unrolled_ref(xs, h0, c0, w_x, w_h, b_)
    np.testing.assert_allclose(hs1, hs2, rtol=1e-5, atol=1e-6)


def test_hoisted_gradients_match():
    """Backward-path hoisting (paper: 'we do similar optimization to move
    the gradient computation part out of the RNN loop'): grads w.r.t. both
    weight matrices must agree between formulations."""
    xs, h0, c0, w_x, w_h, b_ = _setup(3, 5, 8, 8, 16)

    def loss_hoisted(w_x, w_h):
        return jnp.sum(lstm.lstm_layer_hoisted(xs, h0, c0, w_x, w_h, b_) ** 2)

    def loss_ref(w_x, w_h):
        return jnp.sum(ref.lstm_unrolled_ref(xs, h0, c0, w_x, w_h, b_) ** 2)

    g1 = jax.grad(loss_hoisted, argnums=(0, 1))(w_x, w_h)
    g2 = jax.grad(loss_ref, argnums=(0, 1))(w_x, w_h)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_cell_state_bounded():
    """LSTM invariant: |h| < 1 (tanh x sigmoid) regardless of input scale."""
    xs, h0, c0, w_x, w_h, b_ = _setup(11, 4, 8, 8, 16)
    hs = lstm.lstm_layer_hoisted(xs * 100.0, h0, c0, w_x * 10, w_h * 10, b_)
    assert np.all(np.abs(np.asarray(hs)) <= 1.0 + 1e-6)
