"""L1 perf gate: static VMEM-footprint and MXU-utilization estimates
(kernels/vmem.py). A kernel edit that blows the 16 MiB VMEM budget or
de-MXU-shapes a matmul fails here — this is the TPU-perf deliverable that
interpret-mode wallclock cannot give us (DESIGN.md §5).
"""

from compile.kernels import vmem


def test_all_kernels_fit_vmem():
    for e in vmem.ALL_ESTIMATES:
        assert e.vmem_frac < 0.5, (
            f"{e.name} uses {100*e.vmem_frac:.1f}% of VMEM — leaves no room "
            f"for double-buffering")


def test_attention_is_mxu_dominated():
    """The attention kernel's FLOPs must be ≥95% MXU matmuls at production
    sizes — the paper's Transformer hot-spot lives on the systolic array."""
    for s, d in [(128, 64), (256, 64)]:
        e = vmem.attention_estimate(s, d)
        assert e.mxu_utilization > 0.95, (s, d, e.mxu_utilization)


def test_optimizer_kernels_are_memory_bound():
    """Elementwise optimizer updates are HBM-streaming kernels; if the
    estimator ever claims they are compute-bound the model is wrong."""
    assert vmem.lars_update_estimate().roofline_bound == "memory"
    assert vmem.adam_update_estimate().roofline_bound == "memory"


def test_attention_compute_bound_at_scale():
    e = vmem.attention_estimate(256, 64)
    assert e.roofline_bound == "compute"


def test_lstm_small_batch_memory_bound():
    """Paper §3 GNMT: 'When the per-core batch_size is small, the LSTM cell
    computation is memory bound' — the estimator must reproduce that."""
    e = vmem.lstm_cell_estimate(8, 512)
    assert e.roofline_bound == "memory"


def test_gnmt_full_hidden_exceeds_vmem():
    """GNMT's production hidden size (1024 → w_h f32[1024,4096]) does not
    fit a single core's VMEM in f32 — the motivation for bf16 weights and
    weight sharding in the paper's GNMT section."""
    e = vmem.lstm_cell_estimate(8, vmem.GNMT_FULL_HIDDEN)
    assert e.vmem_frac > 1.0


def test_roofline_knee_is_tpu_v3():
    knee = vmem.PEAK_BF16_FLOPS / vmem.HBM_BYTES_PER_S
    assert 100 < knee < 130  # ≈117 FLOP/byte on TPU-v3


def test_report_renders():
    r = vmem.report()
    assert "attention" in r and "lars" in r
