"""AOT pipeline checks: every artifact lowers to parseable HLO text whose
entry computation has the input arity the manifest promises. Runs the real
builder into a temp dir (fast: tiny preset only).
"""

import json
import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(__file__)))


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--presets", "tiny"],
        cwd=os.path.join(REPO, "python"), check=True, capture_output=True)
    with open(out / "manifest.json") as f:
        manifest = json.load(f)
    return out, manifest


def test_manifest_lists_all_files(built):
    out, manifest = built
    for art in manifest["artifacts"]:
        assert (out / art["file"]).exists(), art["name"]


def test_hlo_text_has_entry(built):
    out, manifest = built
    for art in manifest["artifacts"]:
        text = (out / art["file"]).read_text()
        assert "ENTRY" in text, art["name"]
        assert "HloModule" in text, art["name"]


def test_entry_arity_matches_manifest(built):
    """Parameter count in the ENTRY computation must equal the manifest's
    input list — this is the contract the Rust runtime trusts blindly."""
    out, manifest = built
    for art in manifest["artifacts"]:
        text = (out / art["file"]).read_text()
        entry = text[text.index("ENTRY"):]
        body = entry[:entry.index("ROOT")]
        nparams = len(re.findall(r"parameter\(\d+\)", body))
        assert nparams == len(art["inputs"]), art["name"]


def test_train_step_output_arity(built):
    _, manifest = built
    arts = {a["name"]: a for a in manifest["artifacts"]}
    train = arts["transformer_train_tiny"]
    nparams = len(manifest["params"]["transformer_tiny"])
    assert len(train["outputs"]) == 1 + nparams  # loss + one grad per param
    assert len(train["inputs"]) == nparams + 2   # params + tokens + targets


def test_param_manifest_matches_spec(built):
    _, manifest = built
    from compile import model
    from compile.configs import TRANSFORMER_PRESETS
    spec = model.param_spec(TRANSFORMER_PRESETS["tiny"])
    entry = manifest["params"]["transformer_tiny"]
    assert [(e["name"], tuple(e["shape"])) for e in entry] == spec


def test_optimizer_artifacts_present(built):
    _, manifest = built
    names = {a["name"] for a in manifest["artifacts"]}
    assert {"lars_scaled_16384", "lars_unscaled_16384", "adam_16384",
            "attention_b8h4s64d32", "lstm_cell_b8h128"} <= names


def test_rebuild_is_deterministic(built, tmp_path):
    """Same inputs → same HLO hash (Makefile staleness contract)."""
    out, manifest = built
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--presets", "tiny"],
        cwd=os.path.join(REPO, "python"), check=True, capture_output=True)
    with open(tmp_path / "manifest.json") as f:
        manifest2 = json.load(f)
    h1 = {a["name"]: a["sha256"] for a in manifest["artifacts"]}
    h2 = {a["name"]: a["sha256"] for a in manifest2["artifacts"]}
    assert h1 == h2
