"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an oracle here; pytest asserts
allclose between kernel and oracle across shape/dtype sweeps (hypothesis).
The Rust-side optimizers are additionally bit-compared against HLO lowered
from these same functions, closing the three-way loop
(Rust == Pallas == reference).

LARS update equations are the two variants from the paper (Figures 5 and 6):

  scaled momentum (MLPerf-0.6 reference):
      lam = eta * ||w|| / (||g|| + beta * ||w||)
      v   = m * v + (g + beta * w)
      w   = w - lam * v

  unscaled momentum (You et al. [20], the paper's faster variant):
      lam = eta * ||w|| / (||g|| + beta * ||w||)
      v   = m * v + lam * (g + beta * w)
      w   = w - v
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# LARS (paper Figures 5/6)
# ---------------------------------------------------------------------------


def lars_trust_ratio(w, g, eta, beta, eps=1e-9):
    """The LARS layer-adaptive learning rate lambda."""
    w_norm = jnp.sqrt(jnp.sum(jnp.square(w.astype(jnp.float32))))
    g_norm = jnp.sqrt(jnp.sum(jnp.square(g.astype(jnp.float32))))
    return eta * w_norm / (g_norm + beta * w_norm + eps)


def lars_scaled_ref(w, g, v, lr, eta, beta, momentum, eps=1e-9):
    """Scaled-momentum LARS (paper Fig. 5, MLPerf-0.6 reference optimizer)."""
    lam = lars_trust_ratio(w, g, eta, beta, eps)
    v_new = momentum * v + (g + beta * w)
    w_new = w - lr * lam * v_new
    return w_new, v_new


def lars_unscaled_ref(w, g, v, lr, eta, beta, momentum, eps=1e-9):
    """Unscaled-momentum LARS (paper Fig. 6, You et al.)."""
    lam = lars_trust_ratio(w, g, eta, beta, eps)
    v_new = momentum * v + lr * lam * (g + beta * w)
    w_new = w - v_new
    return w_new, v_new


# ---------------------------------------------------------------------------
# Adam (Transformer / GNMT optimizer in the paper)
# ---------------------------------------------------------------------------


def adam_ref(w, g, m, v, step, lr, beta1=0.9, beta2=0.999, eps=1e-8):
    """Standard Adam with bias correction; `step` is 1-based."""
    g = g.astype(jnp.float32)
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * jnp.square(g)
    m_hat = m_new / (1.0 - beta1**step)
    v_hat = v_new / (1.0 - beta2**step)
    w_new = w - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    return w_new, m_new, v_new


# ---------------------------------------------------------------------------
# Attention (Transformer hot-spot)
# ---------------------------------------------------------------------------


def attention_ref(q, k, v, causal=True):
    """Scaled dot-product attention over [B, H, S, D], f32 accumulation.

    Mirrors the paper's mixed-precision rule: matmuls may be bf16 but the
    softmax/normalisation runs in f32.
    """
    b, h, s, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum(
        "bhsd,bhtd->bhst", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
        logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# LSTM cell (GNMT §3): traditional vs hoisted-input-projection formulations
# ---------------------------------------------------------------------------


def lstm_cell_ref(x, h, c, w_x, w_h, b):
    """Traditional LSTM cell: gates from concat([x, h]) (here split weights).

    x: [B, I], h/c: [B, H]; w_x: [I, 4H]; w_h: [H, 4H]; b: [4H].
    Gate order: i, f, g, o.
    """
    gates = x @ w_x + h @ w_h + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def lstm_cell_hoisted_ref(x_proj, h, c, w_h, b):
    """GNMT-optimized cell: input projection x @ w_x precomputed outside the
    recurrent loop (the paper hoists it to run at full effective batch);
    inside the loop only the h-projection remains.
    Mathematically identical to :func:`lstm_cell_ref`.
    """
    gates = x_proj + h @ w_h + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def lstm_unrolled_ref(xs, h0, c0, w_x, w_h, b):
    """Run the traditional cell over a [T, B, I] sequence (oracle for the
    hoisted pipeline)."""

    def step(carry, x):
        h, c = carry
        h, c = lstm_cell_ref(x, h, c, w_x, w_h, b)
        return (h, c), h

    (_, _), hs = jax.lax.scan(step, (h0, c0), xs)
    return hs


def lstm_hoisted_pipeline_ref(xs, h0, c0, w_x, w_h, b):
    """Hoisted formulation over a sequence: one big [T*B, I] @ [I, 4H] matmul
    outside the loop, then the cheap recurrent part. Must equal
    :func:`lstm_unrolled_ref` to float tolerance."""
    t, bsz, _ = xs.shape
    x_proj = (xs.reshape(t * bsz, -1) @ w_x).reshape(t, bsz, -1)

    def step(carry, xp):
        h, c = carry
        h, c = lstm_cell_hoisted_ref(xp, h, c, w_h, b)
        return (h, c), h

    (_, _), hs = jax.lax.scan(step, (h0, c0), x_proj)
    return hs
