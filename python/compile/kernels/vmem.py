"""VMEM-footprint and MXU-utilization estimator for the Pallas kernels.

``interpret=True`` gives CPU-numpy timings only, which say nothing about TPU
performance; what *is* knowable statically is (a) the VMEM working set each
grid step pins, and (b) the fraction of the kernel's FLOPs that land on the
MXU at a given tile shape. These two numbers are the L1 perf deliverable
(DESIGN.md §5) and are asserted in pytest so a kernel edit that blows the
VMEM budget or de-MXU-shapes a matmul fails CI.

TPU-v3 constants (per core):
  VMEM          = 16 MiB
  MXU           = 128x128 systolic array, bf16 multiply / f32 accumulate
  peak bf16     = 52.5 TFLOP/s per core (105 TF/chip / 2 cores, paper Fig. 1:
                  420 TF per 4-chip device)
  HBM bandwidth = 450 GB/s per core (900 GB/chip)
"""

from __future__ import annotations

from dataclasses import dataclass

VMEM_BYTES = 16 * 1024 * 1024
MXU_DIM = 128
PEAK_BF16_FLOPS = 52.5e12
HBM_BYTES_PER_S = 450e9


@dataclass
class KernelEstimate:
    """Static per-grid-step resource estimate for one Pallas kernel."""

    name: str
    vmem_bytes: int          # working set pinned per grid step
    mxu_flops: float         # FLOPs issued as MXU matmuls per grid step
    vpu_flops: float         # FLOPs on the vector unit per grid step
    hbm_bytes: int           # HBM traffic per grid step (stream in + out)

    @property
    def vmem_frac(self) -> float:
        return self.vmem_bytes / VMEM_BYTES

    @property
    def mxu_utilization(self) -> float:
        """Fraction of MXU issue slots filled, accounting for tile padding
        up to the 128x128 systolic array."""
        total = self.mxu_flops + self.vpu_flops
        return 0.0 if total == 0 else self.mxu_flops / total

    @property
    def arithmetic_intensity(self) -> float:
        return (self.mxu_flops + self.vpu_flops) / max(self.hbm_bytes, 1)

    @property
    def roofline_bound(self) -> str:
        knee = PEAK_BF16_FLOPS / HBM_BYTES_PER_S  # ≈117 FLOP/byte on v3
        return "compute" if self.arithmetic_intensity >= knee else "memory"

    def est_step_seconds(self) -> float:
        """Max of compute-limited and memory-limited time per grid step."""
        t_compute = (self.mxu_flops + self.vpu_flops) / PEAK_BF16_FLOPS
        t_memory = self.hbm_bytes / HBM_BYTES_PER_S
        return max(t_compute, t_memory)


def _mxu_padded(m: int, n: int, k: int) -> float:
    """FLOPs a [m,k]@[k,n] matmul *occupies* on the MXU after padding each
    dimension up to the 128 systolic tile (wasted lanes still burn slots)."""
    up = lambda x: -(-x // MXU_DIM) * MXU_DIM
    return 2.0 * up(m) * up(n) * up(k)


def lars_update_estimate(blk: int = 2048) -> KernelEstimate:
    # Elementwise: 5 streams of f32[blk] in (w,g,v,hp,norms≈0) + 2 out.
    return KernelEstimate(
        name="lars_update",
        vmem_bytes=5 * blk * 4,
        mxu_flops=0.0,
        vpu_flops=8.0 * blk,   # mul/add chain per element
        hbm_bytes=5 * blk * 4,
    )


def adam_update_estimate(blk: int = 2048) -> KernelEstimate:
    return KernelEstimate(
        name="adam_update",
        vmem_bytes=7 * blk * 4,
        mxu_flops=0.0,
        vpu_flops=12.0 * blk,
        hbm_bytes=7 * blk * 4,
    )


def attention_estimate(seq: int, dhead: int) -> KernelEstimate:
    # Per (batch*head) grid step: q,k,v,o [S,D] + logits/probs [S,S] in f32.
    vmem = 4 * seq * dhead * 4 + 2 * seq * seq * 4
    qk = _mxu_padded(seq, seq, dhead)
    pv = _mxu_padded(seq, dhead, seq)
    softmax = 6.0 * seq * seq
    return KernelEstimate(
        name=f"attention_s{seq}_d{dhead}",
        vmem_bytes=vmem,
        mxu_flops=qk + pv,
        vpu_flops=softmax,
        hbm_bytes=4 * seq * dhead * 4,
    )


def lstm_cell_estimate(batch_tile: int, hidden: int) -> KernelEstimate:
    # Per grid step: x_proj [Bt,4H], h,c [Bt,H], w_h [H,4H], outputs.
    vmem = (batch_tile * 4 * hidden + 4 * batch_tile * hidden
            + hidden * 4 * hidden + 4 * hidden) * 4
    matmul = _mxu_padded(batch_tile, 4 * hidden, hidden)
    gates = 10.0 * batch_tile * 4 * hidden
    return KernelEstimate(
        name=f"lstm_cell_b{batch_tile}_h{hidden}",
        vmem_bytes=vmem,
        mxu_flops=matmul,
        vpu_flops=gates,
        hbm_bytes=(hidden * 4 * hidden + 6 * batch_tile * hidden) * 4,
    )


ALL_ESTIMATES = [
    lars_update_estimate(),
    adam_update_estimate(),
    attention_estimate(64, 32),
    attention_estimate(128, 64),
    attention_estimate(256, 64),
    lstm_cell_estimate(8, 128),
    lstm_cell_estimate(8, 512),
]

# GNMT's production hidden size does NOT fit: w_h f32[1024, 4096] is 16.8 MB
# alone — the reason the paper's GNMT keeps weights bf16 and the XLA
# weight-update sharding splits optimizer state across cores. Asserted in
# tests/test_vmem.py::test_gnmt_full_hidden_exceeds_vmem.
GNMT_FULL_HIDDEN = 1024


def report() -> str:
    lines = [
        f"{'kernel':<24}{'VMEM':>10}{'%VMEM':>8}{'MXU%':>7}"
        f"{'AI(F/B)':>9}{'bound':>9}"
    ]
    for e in ALL_ESTIMATES:
        lines.append(
            f"{e.name:<24}{e.vmem_bytes:>10}{100*e.vmem_frac:>7.2f}%"
            f"{100*e.mxu_utilization:>6.1f}%{e.arithmetic_intensity:>9.2f}"
            f"{e.roofline_bound:>9}"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
