"""GNMT LSTM cell as a Pallas kernel, in the paper's *hoisted* formulation
(§3 GNMT): the input projection ``x @ w_x`` is lifted out of the recurrent
loop (it has no loop-carried dependency, so it runs at full effective batch
T*B on the MXU); only the hidden-state projection remains inside the loop.

The kernel therefore takes the *pre-projected* input slice ``x_proj`` and
fuses: gates = x_proj + h @ w_h + b → sigmoid/tanh → (h', c').

When the per-core batch is small (the paper's large-pod regime) the cell is
memory-bound: the dominant HBM traffic is streaming w_h [H, 4H]. Hoisting
removes the w_x stream from the loop entirely — halving loop-resident weight
traffic for the encoder's first layer where I == H.

Grid: one step per batch tile of :data:`BATCH_TILE` rows; w_h is re-read per
tile (on TPU it would stay VMEM-resident across grid steps on the innermost
dimension).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BATCH_TILE = 8


def _cell_kernel(xp_ref, h_ref, c_ref, wh_ref, b_ref, h_out_ref, c_out_ref):
    gates = (
        xp_ref[...].astype(jnp.float32)
        + jnp.dot(h_ref[...].astype(jnp.float32), wh_ref[...].astype(jnp.float32))
        + b_ref[...].astype(jnp.float32)
    )
    hdim = h_ref.shape[-1]
    i = jax.nn.sigmoid(gates[:, 0 * hdim:1 * hdim])
    f = jax.nn.sigmoid(gates[:, 1 * hdim:2 * hdim])
    g = jnp.tanh(gates[:, 2 * hdim:3 * hdim])
    o = jax.nn.sigmoid(gates[:, 3 * hdim:4 * hdim])
    c_new = f * c_ref[...].astype(jnp.float32) + i * g
    h_out_ref[...] = o * jnp.tanh(c_new)
    c_out_ref[...] = c_new


def _cell_forward(x_proj, h, c, w_h, b):
    bsz, hdim = h.shape
    assert bsz % BATCH_TILE == 0, f"batch {bsz} not a multiple of {BATCH_TILE}"
    ntile = bsz // BATCH_TILE
    xp_spec = pl.BlockSpec((BATCH_TILE, 4 * hdim), lambda i: (i, 0))
    st_spec = pl.BlockSpec((BATCH_TILE, hdim), lambda i: (i, 0))
    wh_spec = pl.BlockSpec((hdim, 4 * hdim), lambda i: (0, 0))
    b_spec = pl.BlockSpec((4 * hdim,), lambda i: (0,))
    h_new, c_new = pl.pallas_call(
        _cell_kernel,
        grid=(ntile,),
        in_specs=[xp_spec, st_spec, st_spec, wh_spec, b_spec],
        out_specs=[st_spec, st_spec],
        out_shape=[jax.ShapeDtypeStruct((bsz, hdim), jnp.float32)] * 2,
        interpret=True,
    )(x_proj, h, c, w_h, b)
    return h_new, c_new


@jax.custom_vjp
def lstm_cell_hoisted(x_proj, h, c, w_h, b):
    """One fused hoisted-LSTM cell step.

    x_proj: [B, 4H] (already x @ w_x); h, c: [B, H]; w_h: [H, 4H]; b: [4H].
    B must be a multiple of BATCH_TILE (callers pad). Returns (h', c').

    Differentiable via a hand-written VJP (pallas_call in interpret mode has
    no reverse rule): the backward recomputes the gates from the saved cell
    inputs — the same compute-for-memory trade as the attention kernel,
    which is what lets the paper keep the backward *outside* the RNN loop.
    """
    return _cell_forward(x_proj, h, c, w_h, b)


def _cell_vjp_fwd(x_proj, h, c, w_h, b):
    out = _cell_forward(x_proj, h, c, w_h, b)
    return out, (x_proj, h, c, w_h, b)


def _cell_vjp_bwd(res, cot):
    x_proj, h, c, w_h, b = res
    do_h, do_c = cot
    hdim = h.shape[-1]
    gates = x_proj + h @ w_h + b
    i = jax.nn.sigmoid(gates[:, 0 * hdim:1 * hdim])
    f = jax.nn.sigmoid(gates[:, 1 * hdim:2 * hdim])
    g = jnp.tanh(gates[:, 2 * hdim:3 * hdim])
    o = jax.nn.sigmoid(gates[:, 3 * hdim:4 * hdim])
    c_new = f * c + i * g
    tc = jnp.tanh(c_new)
    d_c_new = do_h * o * (1.0 - tc * tc) + do_c
    d_i = d_c_new * g * i * (1.0 - i)
    d_f = d_c_new * c * f * (1.0 - f)
    d_g = d_c_new * i * (1.0 - g * g)
    d_o = do_h * tc * o * (1.0 - o)
    d_gates = jnp.concatenate([d_i, d_f, d_g, d_o], axis=-1)
    d_xproj = d_gates
    d_h = d_gates @ w_h.T
    d_c = d_c_new * f
    d_wh = h.T @ d_gates
    d_b = jnp.sum(d_gates, axis=0)
    return d_xproj, d_h, d_c, d_wh, d_b


lstm_cell_hoisted.defvjp(_cell_vjp_fwd, _cell_vjp_bwd)


def lstm_layer_hoisted(xs, h0, c0, w_x, w_h, b):
    """Full hoisted LSTM layer over [T, B, I]: one big projection outside the
    scan (T*B effective batch — the paper's optimization), Pallas cell inside.
    Returns hidden states [T, B, H]."""
    t, bsz, idim = xs.shape
    x_proj = (xs.reshape(t * bsz, idim) @ w_x).reshape(t, bsz, -1)

    def step(carry, xp):
        h, c = carry
        h, c = lstm_cell_hoisted(xp, h, c, w_h, b)
        return (h, c), h

    (_, _), hs = jax.lax.scan(step, (h0, c0), x_proj)
    return hs
