"""Fused LARS weight-update Pallas kernel (paper §2 "weight update sharding",
§3 ResNet-50; update equations from Figures 5/6).

Two kernels compose the update so the structure matches what a real TPU
lowering would do for a sharded optimizer:

  1. ``norms_kernel`` — blocked partial sum-of-squares reduction over the
     (flattened) weight and gradient tensors, one grid step per ``BLK``
     elements, partials accumulated in f32 (mixed-precision rule: reductions
     in f32 even when weights are bf16-backed).
  2. ``update_kernel`` — elementwise fused update, one grid step per block,
     consuming the two scalar norms plus the hyper-parameter vector.

Both LARS variants share the kernel; the variant is a compile-time flag so
the branch is resolved at lowering (no runtime divergence on TPU).

Hyper-parameters ride in a ``f32[4]`` tensor ``[lr, eta, beta, momentum]`` so
the Rust coordinator can anneal the learning rate without recompiling the
artifact.

All shapes must be padded to a multiple of :data:`BLK` by the caller
(:func:`lars_update` pads internally for convenience); padded elements MUST
be zero in ``w``/``g`` so they do not perturb the norms.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size: 8 KiB of f32 per operand — 5 operands resident (w, g, v, out
# w', out v') ≈ 40 KiB VMEM per grid step, far under the 16 MiB/core budget;
# chosen so a 2048-way sharded ResNet-50 shard (~12.5K params) is 7 blocks.
BLK = 2048


def _norms_kernel(w_ref, g_ref, out_ref):
    """Partial sum-of-squares per block: out[i] = [sum(w^2), sum(g^2)]."""
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    out_ref[0] = jnp.sum(w * w)
    out_ref[1] = jnp.sum(g * g)


def _update_kernel(scaled: bool, w_ref, g_ref, v_ref, hp_ref, norms_ref,
                   w_out_ref, v_out_ref):
    lr, eta, beta, momentum = hp_ref[0], hp_ref[1], hp_ref[2], hp_ref[3]
    w_norm = jnp.sqrt(norms_ref[0])
    g_norm = jnp.sqrt(norms_ref[1])
    lam = eta * w_norm / (g_norm + beta * w_norm + 1e-9)
    w = w_ref[...]
    g = g_ref[...]
    v = v_ref[...]
    update = g + beta * w
    if scaled:
        # Fig. 5 (MLPerf-0.6 reference): momentum buffer holds raw updates,
        # the trust ratio scales the *step*.
        v_new = momentum * v + update
        w_new = w - lr * lam * v_new
    else:
        # Fig. 6 (You et al.): trust ratio folded into the momentum buffer.
        v_new = momentum * v + lr * lam * update
        w_new = w - v_new
    w_out_ref[...] = w_new
    v_out_ref[...] = v_new


def lars_norms(w, g):
    """Blocked partial-norm reduction; returns f32[2] = [||w||^2, ||g||^2]."""
    n = w.shape[0]
    assert n % BLK == 0, f"size {n} not padded to BLK={BLK}"
    nblk = n // BLK
    partials = pl.pallas_call(
        _norms_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((BLK,), lambda i: (i,)),
            pl.BlockSpec((BLK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((2,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((2 * nblk,), jnp.float32),
        interpret=True,
    )(w, g)
    return jnp.sum(partials.reshape(nblk, 2), axis=0)


def lars_apply(w, g, v, hp, norms, *, scaled: bool):
    """Elementwise fused LARS update given precomputed squared norms."""
    n = w.shape[0]
    assert n % BLK == 0
    nblk = n // BLK
    kernel = functools.partial(_update_kernel, scaled)
    scalar_spec = pl.BlockSpec((4,), lambda i: (0,))
    norm_spec = pl.BlockSpec((2,), lambda i: (0,))
    blk_spec = pl.BlockSpec((BLK,), lambda i: (i,))
    w_new, v_new = pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=[blk_spec, blk_spec, blk_spec, scalar_spec, norm_spec],
        out_specs=[blk_spec, blk_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(w, g, v, hp, norms)
    return w_new, v_new


def lars_update(w, g, v, hp, *, scaled: bool):
    """Full fused LARS step on a flat tensor of any length (auto-pads).

    hp = f32[4] = [lr, eta, beta, momentum]. Returns (w', v').
    """
    n = w.shape[0]
    pad = (-n) % BLK
    if pad:
        w = jnp.pad(w, (0, pad))
        g = jnp.pad(g, (0, pad))
        v = jnp.pad(v, (0, pad))
    norms = lars_norms(w, g)
    w_new, v_new = lars_apply(w, g, v, hp, norms, scaled=scaled)
    if pad:
        w_new, v_new = w_new[:n], v_new[:n]
    return w_new, v_new
