"""Causal scaled-dot-product attention as a Pallas kernel pair (fwd + bwd),
wired through ``jax.custom_vjp`` so the L2 transformer's autodiff uses the
hand-written backward kernel.

This is the Transformer hot-spot from paper §3 ("transformers typically have
attention layers that are large fully connected layers"). TPU shaping:

  * grid = (batch * heads,): each grid step owns one full [S, D] attention
    problem resident in VMEM — for the sizes this repo trains (S ≤ 256,
    D ≤ 128) the working set is S*D*3*4B + S*S*4B ≤ 640 KiB, comfortably
    inside the 16 MiB/core VMEM budget (see kernels/vmem.py for the audit).
  * logits/softmax in f32 even if q/k/v arrive bf16 — the paper's
    mixed-precision rule (non-conv/matmul math in f32).
  * the S×S logits matmul and the PV matmul are MXU-shaped
    ([S,D]@[D,S], [S,S]@[S,D]).

The backward kernel recomputes the probability matrix from q,k (cheaper than
spilling S×S residuals to HBM — the standard TPU trade, compute for memory).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref):
    s, d = q_ref.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    logits = jnp.dot(q, k.T) * scale
    rows = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    logits = jnp.where(cols <= rows, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v_ref[...].astype(jnp.float32))


def _bwd_kernel(q_ref, k_ref, v_ref, do_ref, dq_ref, dk_ref, dv_ref):
    s, d = q_ref.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    logits = jnp.dot(q, k.T) * scale
    rows = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    logits = jnp.where(cols <= rows, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    dv_ref[...] = jnp.dot(p.T, do)
    dp = jnp.dot(do, v.T)
    # softmax VJP: dlogits = p * (dp - sum(dp * p, axis=-1))
    dlogits = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq_ref[...] = jnp.dot(dlogits, k) * scale
    dk_ref[...] = jnp.dot(dlogits.T, q) * scale


def _flatten_heads(x):
    b, h, s, d = x.shape
    return x.reshape(b * h, s, d)


def _attention_fwd_impl(q, k, v):
    b, h, s, d = q.shape
    qf, kf, vf = _flatten_heads(q), _flatten_heads(k), _flatten_heads(v)
    spec = pl.BlockSpec((1, s, d), lambda i: (i, 0, 0))

    def kernel(q_ref, k_ref, v_ref, o_ref):
        _fwd_kernel(q_ref.at[0], k_ref.at[0], v_ref.at[0], o_ref.at[0])

    o = pl.pallas_call(
        kernel,
        grid=(b * h,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), jnp.float32),
        interpret=True,
    )(qf, kf, vf)
    return o.reshape(b, h, s, d)


def _attention_bwd_impl(q, k, v, do):
    b, h, s, d = q.shape
    qf, kf, vf, dof = (_flatten_heads(t) for t in (q, k, v, do))
    spec = pl.BlockSpec((1, s, d), lambda i: (i, 0, 0))

    def kernel(q_ref, k_ref, v_ref, do_ref, dq_ref, dk_ref, dv_ref):
        _bwd_kernel(q_ref.at[0], k_ref.at[0], v_ref.at[0], do_ref.at[0],
                    dq_ref.at[0], dk_ref.at[0], dv_ref.at[0])

    dq, dk, dv = pl.pallas_call(
        kernel,
        grid=(b * h,),
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((b * h, s, d), jnp.float32)] * 3,
        interpret=True,
    )(qf, kf, vf, dof)
    return (dq.reshape(b, h, s, d), dk.reshape(b, h, s, d),
            dv.reshape(b, h, s, d))


@jax.custom_vjp
def attention(q, k, v):
    """Causal attention over [B, H, S, D]; differentiable via the Pallas
    backward kernel."""
    return _attention_fwd_impl(q, k, v)


def _vjp_fwd(q, k, v):
    return _attention_fwd_impl(q, k, v), (q, k, v)


def _vjp_bwd(res, do):
    q, k, v = res
    return _attention_bwd_impl(q, k, v, do)


attention.defvjp(_vjp_fwd, _vjp_bwd)
