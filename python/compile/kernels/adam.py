"""Fused Adam weight-update Pallas kernel (paper §2: "the ADAM optimizer
weight update time is about 45% of the step time" for Transformer — the
motivation for weight-update sharding).

Elementwise over a flat f32 tensor, blocked at :data:`BLK` elements per grid
step. Hyper-parameters ride in ``f32[5] = [lr, beta1, beta2, eps, step]``
(``step`` 1-based, carried as f32 so one artifact serves every step; TPU
lowering would keep it in SMEM).

Why fusion matters (paper §2): an unfused Adam update reads/writes each of
w, g, m, v from HBM several times across ~10 HLO ops; the fused kernel
streams each operand exactly once — the same reduction in HBM traffic that
weight-update sharding then divides across cores.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK = 2048


def _adam_kernel(w_ref, g_ref, m_ref, v_ref, hp_ref,
                 w_out_ref, m_out_ref, v_out_ref):
    lr, beta1, beta2, eps, step = (
        hp_ref[0], hp_ref[1], hp_ref[2], hp_ref[3], hp_ref[4]
    )
    g = g_ref[...].astype(jnp.float32)
    m_new = beta1 * m_ref[...] + (1.0 - beta1) * g
    v_new = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    # Bias correction: beta^step via exp(step * log(beta)) — transcendental
    # on the scalar unit, hoisted out of the vector loop by the compiler.
    bc1 = 1.0 - jnp.exp(step * jnp.log(beta1))
    bc2 = 1.0 - jnp.exp(step * jnp.log(beta2))
    m_hat = m_new / bc1
    v_hat = v_new / bc2
    w_out_ref[...] = w_ref[...] - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    m_out_ref[...] = m_new
    v_out_ref[...] = v_new


def adam_apply(w, g, m, v, hp):
    """Fused Adam on BLK-padded flat tensors. hp=[lr,b1,b2,eps,step]."""
    n = w.shape[0]
    assert n % BLK == 0, f"size {n} not padded to BLK={BLK}"
    nblk = n // BLK
    blk = pl.BlockSpec((BLK,), lambda i: (i,))
    scalar = pl.BlockSpec((5,), lambda i: (0,))
    return pl.pallas_call(
        _adam_kernel,
        grid=(nblk,),
        in_specs=[blk, blk, blk, blk, scalar],
        out_specs=[blk, blk, blk],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=True,
    )(w, g, m, v, hp)


def adam_update(w, g, m, v, hp):
    """Auto-padding wrapper; returns (w', m', v') at the original length."""
    n = w.shape[0]
    pad = (-n) % BLK
    if pad:
        w, g, m, v = (jnp.pad(t, (0, pad)) for t in (w, g, m, v))
    w_new, m_new, v_new = adam_apply(w, g, m, v, hp)
    if pad:
        w_new, m_new, v_new = w_new[:n], m_new[:n], v_new[:n]
    return w_new, m_new, v_new
