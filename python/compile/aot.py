"""AOT pipeline: lower every L2 entry point to HLO **text** + write
artifacts/manifest.json describing shapes for the Rust runtime.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. Lowered with ``return_tuple=True`` —
the Rust side unwraps the tuple.

Run: ``python -m compile.aot --out-dir ../artifacts [--presets tiny,small]``
(the Makefile invokes this; it is a no-op at runtime — Python never touches
the request path).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import cnn, model
from .configs import CNN_PRESETS, TRANSFORMER_PRESETS
from .kernels import adam as adam_k
from .kernels import attention as attn_k
from .kernels import lars as lars_k
from .kernels import lstm as lstm_k

# Canonical flat-tensor size for the optimizer artifacts: covers one
# weight-update shard of the mini models and proves the Rust⇄Pallas loop.
OPT_SIZE = 16384


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io(name, dtype, shape):
    return {"name": name, "dtype": dtype, "shape": list(shape)}


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {"artifacts": [], "params": {}, "configs": {}}
        os.makedirs(out_dir, exist_ok=True)

    def add(self, name, fn, in_specs, inputs, outputs, meta=None):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(self.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        self.manifest["artifacts"].append({
            "name": name,
            "file": fname,
            "inputs": inputs,
            "outputs": outputs,
            "meta": meta or {},
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        })
        print(f"  {name}: {len(text)/1e6:.2f} MB HLO in "
              f"{time.time()-t0:.1f}s", file=sys.stderr)

    def write_manifest(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"  manifest.json: {len(self.manifest['artifacts'])} artifacts",
              file=sys.stderr)


def build_transformer(b: Builder, preset: str):
    cfg = TRANSFORMER_PRESETS[preset]
    spec = model.param_spec(cfg)
    b.manifest["params"][f"transformer_{preset}"] = [
        {"name": n, "shape": list(s)} for n, s in spec]
    b.manifest["configs"][f"transformer_{preset}"] = cfg.__dict__.copy()
    p_specs = [_spec(s) for _, s in spec]
    tok = _spec((cfg.batch_per_core, cfg.seq), jnp.int32)

    b.add(
        f"transformer_train_{preset}", model.make_train_step(cfg),
        p_specs + [tok, tok],
        inputs=[_io(n, "f32", s) for n, s in spec]
        + [_io("tokens", "i32", tok.shape), _io("targets", "i32", tok.shape)],
        outputs=[_io("loss", "f32", ())]
        + [_io(f"grad.{n}", "f32", s) for n, s in spec],
        meta={"model": f"transformer_{preset}", "kind": "train_step"},
    )
    mask = _spec((cfg.batch_per_core,), jnp.float32)
    b.add(
        f"transformer_eval_{preset}", model.make_eval_step(cfg),
        p_specs + [tok, tok, mask],
        inputs=[_io(n, "f32", s) for n, s in spec]
        + [_io("tokens", "i32", tok.shape), _io("targets", "i32", tok.shape),
           _io("mask", "f32", mask.shape)],
        outputs=[_io("loss_sum", "f32", ()), _io("correct", "f32", ()),
                 _io("count", "f32", ())],
        meta={"model": f"transformer_{preset}", "kind": "eval_step"},
    )


def build_cnn(b: Builder, preset: str):
    cfg = CNN_PRESETS[preset]
    spec = cnn.param_spec(cfg)
    b.manifest["params"][f"cnn_{preset}"] = [
        {"name": n, "shape": list(s)} for n, s in spec]
    b.manifest["configs"][f"cnn_{preset}"] = cfg.__dict__.copy()
    p_specs = [_spec(s) for _, s in spec]
    img = _spec((cfg.batch_per_core, cfg.image, cfg.image, 3), jnp.float32)
    lab = _spec((cfg.batch_per_core,), jnp.int32)

    b.add(
        f"cnn_train_{preset}", cnn.make_train_step(cfg),
        p_specs + [img, lab],
        inputs=[_io(n, "f32", s) for n, s in spec]
        + [_io("images", "f32", img.shape), _io("labels", "i32", lab.shape)],
        outputs=[_io("loss", "f32", ())]
        + [_io(f"grad.{n}", "f32", s) for n, s in spec],
        meta={"model": f"cnn_{preset}", "kind": "train_step"},
    )
    mask = _spec((cfg.batch_per_core,), jnp.float32)
    b.add(
        f"cnn_eval_{preset}", cnn.make_eval_step(cfg),
        p_specs + [img, lab, mask],
        inputs=[_io(n, "f32", s) for n, s in spec]
        + [_io("images", "f32", img.shape), _io("labels", "i32", lab.shape),
           _io("mask", "f32", mask.shape)],
        outputs=[_io("loss_sum", "f32", ()), _io("correct", "f32", ()),
                 _io("count", "f32", ())],
        meta={"model": f"cnn_{preset}", "kind": "eval_step"},
    )


def build_optimizers(b: Builder):
    n = OPT_SIZE
    vec = _spec((n,))
    hp4, hp5 = _spec((4,)), _spec((5,))
    for scaled, name in [(True, "lars_scaled"), (False, "lars_unscaled")]:
        b.add(
            f"{name}_{n}",
            lambda w, g, v, hp, s=scaled: lars_k.lars_update(
                w, g, v, hp, scaled=s),
            [vec, vec, vec, hp4],
            inputs=[_io("w", "f32", (n,)), _io("g", "f32", (n,)),
                    _io("v", "f32", (n,)),
                    _io("hp[lr,eta,beta,mom]", "f32", (4,))],
            outputs=[_io("w_new", "f32", (n,)), _io("v_new", "f32", (n,))],
            meta={"kind": "optimizer", "algo": name, "size": n},
        )
    b.add(
        f"adam_{n}",
        lambda w, g, m, v, hp: adam_k.adam_update(w, g, m, v, hp),
        [vec, vec, vec, vec, hp5],
        inputs=[_io("w", "f32", (n,)), _io("g", "f32", (n,)),
                _io("m", "f32", (n,)), _io("v", "f32", (n,)),
                _io("hp[lr,b1,b2,eps,step]", "f32", (5,))],
        outputs=[_io("w_new", "f32", (n,)), _io("m_new", "f32", (n,)),
                 _io("v_new", "f32", (n,))],
        meta={"kind": "optimizer", "algo": "adam", "size": n},
    )


def build_kernel_micro(b: Builder):
    # Standalone attention (runtime micro-bench target).
    bh, s, d = (8, 4), 64, 32
    q = _spec((bh[0], bh[1], s, d))
    b.add(
        "attention_b8h4s64d32",
        lambda q, k, v: attn_k.attention(q, k, v),
        [q, q, q],
        inputs=[_io(t, "f32", q.shape) for t in ("q", "k", "v")],
        outputs=[_io("o", "f32", q.shape)],
        meta={"kind": "kernel", "algo": "attention"},
    )
    # Hoisted LSTM cell (GNMT §3).
    bsz, h = 8, 128
    b.add(
        "lstm_cell_b8h128",
        lambda xp, hh, cc, wh, bb: lstm_k.lstm_cell_hoisted(xp, hh, cc, wh, bb),
        [_spec((bsz, 4 * h)), _spec((bsz, h)), _spec((bsz, h)),
         _spec((h, 4 * h)), _spec((4 * h,))],
        inputs=[_io("x_proj", "f32", (bsz, 4 * h)),
                _io("h", "f32", (bsz, h)), _io("c", "f32", (bsz, h)),
                _io("w_h", "f32", (h, 4 * h)), _io("b", "f32", (4 * h,))],
        outputs=[_io("h_new", "f32", (bsz, h)), _io("c_new", "f32", (bsz, h))],
        meta={"kind": "kernel", "algo": "lstm_cell_hoisted"},
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", default="tiny,small",
                    help="transformer presets to build (comma-sep)")
    ap.add_argument("--cnn-presets", default="mini")
    args = ap.parse_args()

    b = Builder(args.out_dir)
    for preset in [p for p in args.presets.split(",") if p]:
        build_transformer(b, preset)
    for preset in [p for p in args.cnn_presets.split(",") if p]:
        build_cnn(b, preset)
    build_optimizers(b)
    build_kernel_micro(b)
    b.write_manifest()


if __name__ == "__main__":
    main()
