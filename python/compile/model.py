"""L2: transformer language model fwd/bwd in JAX, calling the L1 Pallas
attention kernel, shaped like the paper's MLPerf Transformer workload.

The train step deliberately returns **(loss, grads...)** rather than updated
weights: the optimizer is the Rust coordinator's job (paper §2 weight-update
sharding — the update is sharded across cores *after* gradient summation, so
it cannot live inside the per-core fwd/bwd HLO).

Mixed precision follows the paper's rule: matmul/attention operands are cast
to bfloat16 with f32 accumulation; layer-norm, softmax, loss and gradient
summation stay f32.

Parameters travel as a flat ordered list of tensors. ``param_spec`` is the
single source of truth for that order; aot.py serialises it into
artifacts/manifest.json so the Rust side can allocate/iterate identically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import TransformerConfig
from .kernels.attention import attention


# ---------------------------------------------------------------------------
# Parameter spec / init
# ---------------------------------------------------------------------------


def param_spec(cfg: TransformerConfig):
    """Ordered [(name, shape)] for every trainable tensor."""
    spec = [("embed", (cfg.vocab, cfg.d_model))]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        spec += [
            (p + "ln1.scale", (cfg.d_model,)),
            (p + "ln1.bias", (cfg.d_model,)),
            (p + "attn.wq", (cfg.d_model, cfg.d_model)),
            (p + "attn.wk", (cfg.d_model, cfg.d_model)),
            (p + "attn.wv", (cfg.d_model, cfg.d_model)),
            (p + "attn.wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2.scale", (cfg.d_model,)),
            (p + "ln2.bias", (cfg.d_model,)),
            (p + "mlp.w1", (cfg.d_model, cfg.d_ff)),
            (p + "mlp.b1", (cfg.d_ff,)),
            (p + "mlp.w2", (cfg.d_ff, cfg.d_model)),
            (p + "mlp.b2", (cfg.d_model,)),
        ]
    spec += [("ln_f.scale", (cfg.d_model,)), ("ln_f.bias", (cfg.d_model,))]
    return spec


def init_params(cfg: TransformerConfig, key):
    """Scaled-normal init; scale/bias tensors start at 1/0."""
    params = []
    for i, (name, shape) in enumerate(param_spec(cfg)):
        if name.endswith(".scale"):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".bias", ".b1", ".b2")):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0]
            std = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
            params.append(
                std * jax.random.normal(jax.random.fold_in(key, i), shape,
                                        jnp.float32))
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _matmul(x, w, mixed: bool):
    """Paper mixed-precision rule: bf16 operands, f32 accumulation."""
    if mixed:
        return jnp.dot(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    return jnp.dot(x, w)


def _layer_norm(x, scale, bias, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def forward(cfg: TransformerConfig, params, tokens):
    """tokens [B, S] int32 → logits [B, S, V] f32 (weight-tied output)."""
    it = iter(params)
    nxt = lambda: next(it)
    embed = nxt()
    x = embed[tokens]  # [B, S, D]
    b, s, d = x.shape
    for _ in range(cfg.n_layers):
        ln1s, ln1b = nxt(), nxt()
        wq, wk, wv, wo = nxt(), nxt(), nxt(), nxt()
        ln2s, ln2b = nxt(), nxt()
        w1, b1, w2, b2 = nxt(), nxt(), nxt(), nxt()
        h = _layer_norm(x, ln1s, ln1b)
        q = _matmul(h, wq, cfg.mixed_bf16)
        k = _matmul(h, wk, cfg.mixed_bf16)
        v = _matmul(h, wv, cfg.mixed_bf16)
        split = lambda t: t.reshape(b, s, cfg.n_heads, cfg.d_head).transpose(
            0, 2, 1, 3)
        o = attention(split(q), split(k), split(v))  # L1 Pallas kernel
        o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
        x = x + _matmul(o, wo, cfg.mixed_bf16)
        h = _layer_norm(x, ln2s, ln2b)
        h = jax.nn.relu(_matmul(h, w1, cfg.mixed_bf16) + b1)
        x = x + _matmul(h, w2, cfg.mixed_bf16) + b2
    lnfs, lnfb = nxt(), nxt()
    x = _layer_norm(x, lnfs, lnfb)
    return _matmul(x, embed.T, cfg.mixed_bf16)  # tied softmax weights


def _token_losses(cfg, params, tokens, targets):
    """Per-token NLL [B, S], f32 (softmax in f32 per the paper)."""
    logits = forward(cfg, params, tokens).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]


def loss_fn(cfg: TransformerConfig, params, tokens, targets):
    return jnp.mean(_token_losses(cfg, params, tokens, targets))


# ---------------------------------------------------------------------------
# AOT entry points
# ---------------------------------------------------------------------------


def make_train_step(cfg: TransformerConfig):
    """(params..., tokens, targets) → (loss, grads...) — grads in param_spec
    order, f32, ready for the Rust 2-D gradient summation."""

    def train_step(*args):
        nparams = len(param_spec(cfg))
        params = list(args[:nparams])
        tokens, targets = args[nparams], args[nparams + 1]
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, tokens, targets))(params)
        return (loss, *grads)

    return train_step


def make_eval_step(cfg: TransformerConfig):
    """(params..., tokens, targets, mask) → (loss_sum, correct, count).

    ``mask`` is f32[B]: 1 for real eval examples, 0 for the zero-padding the
    distributed evaluator adds so the eval set divides the core count
    (paper §2 'Distribute evaluation computation'). Only masked-in tokens
    contribute — the Rust side just sums the three scalars across cores.
    """

    def eval_step(*args):
        nparams = len(param_spec(cfg))
        params = list(args[:nparams])
        tokens, targets, mask = args[nparams:nparams + 3]
        losses = _token_losses(cfg, params, tokens, targets)  # [B, S]
        logits = forward(cfg, params, tokens)
        pred = jnp.argmax(logits, axis=-1)
        correct = (pred == targets).astype(jnp.float32)
        m = mask[:, None]
        count = jnp.sum(m * jnp.ones_like(losses))
        return (jnp.sum(losses * m), jnp.sum(correct * m), count)

    return eval_step
