"""Model-size presets for the AOT artifacts.

Per-core batch lives here because HLO is shape-specialised: the Rust
coordinator picks an artifact whose ``batch_per_core`` matches its
data-parallel layout (global batch = batch_per_core x num_cores, paper §4
Fig. 7).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    vocab: int
    d_model: int
    n_heads: int
    d_ff: int
    n_layers: int
    seq: int
    batch_per_core: int
    mixed_bf16: bool = True  # paper §2: matmuls bf16, everything else f32

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


@dataclass(frozen=True)
class CnnConfig:
    name: str
    image: int            # square side
    channels: tuple
    classes: int
    batch_per_core: int
    mixed_bf16: bool = True


TRANSFORMER_PRESETS = {
    # tiny: unit tests + quickstart; one train step is a few ms on CPU.
    "tiny": TransformerConfig("tiny", vocab=256, d_model=128, n_heads=4,
                              d_ff=256, n_layers=2, seq=64, batch_per_core=8),
    # small: the e2e_train default (~3.6M params).
    "small": TransformerConfig("small", vocab=1024, d_model=256, n_heads=8,
                               d_ff=1024, n_layers=4, seq=128,
                               batch_per_core=8),
    # large: scaling study (~27M params); build with PRESETS=large.
    "large": TransformerConfig("large", vocab=8192, d_model=512, n_heads=8,
                               d_ff=2048, n_layers=8, seq=128,
                               batch_per_core=4),
}

CNN_PRESETS = {
    # mini: the LARS study model (3 conv blocks + fc, batch-norm'd).
    "mini": CnnConfig("mini", image=16, channels=(16, 32, 64), classes=10,
                      batch_per_core=32),
}
