"""L2: mini convolutional classifier (ResNet-50 stand-in) for the LARS
optimizer study (paper §3, Table 1).

Three conv+batch-norm+relu blocks with 2x2 average pooling, then a linear
head — small enough that a full batch-size/optimizer sweep runs on CPU in
seconds, but with the property the LARS study needs: many weight tensors of
very different scale (conv kernels vs. BN scales vs. the head), which is
exactly the regime where layer-adaptive rates matter.

Batch norm uses batch statistics in both train and eval (the distributed
batch-norm of the paper is a *cross-core* statistics group; the grouping
itself lives in the Rust layer — see rust/src/models/batchnorm.rs — while
this per-core graph computes the local moments it would feed in).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import CnnConfig


def param_spec(cfg: CnnConfig):
    spec = []
    in_c = 3
    for i, out_c in enumerate(cfg.channels):
        spec += [
            (f"conv{i}.w", (3, 3, in_c, out_c)),
            (f"bn{i}.scale", (out_c,)),
            (f"bn{i}.bias", (out_c,)),
        ]
        in_c = out_c
    side = cfg.image // (2 ** len(cfg.channels))
    feat = side * side * cfg.channels[-1]
    spec += [("fc.w", (feat, cfg.classes)), ("fc.b", (cfg.classes,))]
    return spec


def init_params(cfg: CnnConfig, key):
    params = []
    for i, (name, shape) in enumerate(param_spec(cfg)):
        if name.endswith(".scale"):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".bias", ".b")):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            std = jnp.sqrt(2.0 / fan_in)
            params.append(
                std * jax.random.normal(jax.random.fold_in(key, i), shape,
                                        jnp.float32))
    return params


def _round_bf16(x):
    """bf16 mantissa rounding with f32 storage: same numerics as bf16
    operands + f32 accumulation, but keeps the conv VJP single-dtype
    (lax.conv's transpose rule rejects mixed bf16/f32 operands)."""
    return x.astype(jnp.bfloat16).astype(jnp.float32)


def _conv(x, w, mixed: bool):
    if mixed:
        x, w = _round_bf16(x), _round_bf16(w)
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32)


def _batch_norm(x, scale, bias, eps=1e-5):
    # f32 moments over (N, H, W) — the non-conv op the paper keeps in f32.
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=(0, 1, 2))
    var = jnp.mean(jnp.square(x - mu), axis=(0, 1, 2))
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def forward(cfg: CnnConfig, params, images):
    """images [B, I, I, 3] f32 → logits [B, classes] f32."""
    it = iter(params)
    x = images
    for _ in cfg.channels:
        w, s, b = next(it), next(it), next(it)
        x = jax.nn.relu(_batch_norm(_conv(x, w, cfg.mixed_bf16), s, b))
        x = jax.lax.reduce_window(
            x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0
    fcw, fcb = next(it), next(it)
    x = x.reshape(x.shape[0], -1)
    if cfg.mixed_bf16:
        logits = jnp.dot(x.astype(jnp.bfloat16), fcw.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32) + fcb
    else:
        logits = x @ fcw + fcb
    return logits


def loss_fn(cfg: CnnConfig, params, images, labels):
    logits = forward(cfg, params, images).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def make_train_step(cfg: CnnConfig):
    """(params..., images, labels) → (loss, grads...)."""

    def train_step(*args):
        nparams = len(param_spec(cfg))
        params = list(args[:nparams])
        images, labels = args[nparams], args[nparams + 1]
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, images, labels))(params)
        return (loss, *grads)

    return train_step


def make_eval_step(cfg: CnnConfig):
    """(params..., images, labels, mask) → (loss_sum, correct, count) —
    masked for the distributed evaluator's zero-padded examples."""

    def eval_step(*args):
        nparams = len(param_spec(cfg))
        params = list(args[:nparams])
        images, labels, mask = args[nparams:nparams + 3]
        logits = forward(cfg, params, images).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        losses = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        correct = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
        return (jnp.sum(losses * mask), jnp.sum(correct * mask),
                jnp.sum(mask))

    return eval_step
