//! Quickstart: train the tiny transformer LM for 60 steps on 4 simulated
//! TPU cores, with every paper technique on its default setting. Runs on
//! the in-Rust reference backend — no artifacts needed.
//!
//!   cargo run --release --example quickstart

use tpu_pod_train::coordinator::{train, GradSumMode, OptChoice, TrainConfig};
use tpu_pod_train::metrics::TraceSink;
use tpu_pod_train::optim::AdamConfig;
use tpu_pod_train::runtime::BackendChoice;

fn main() -> anyhow::Result<()> {
    let cfg = TrainConfig {
        model: "transformer_tiny".into(),
        cores: 4,
        steps: 60,
        eval_every: 20,
        eval_examples: 128,
        opt: OptChoice::Adam { cfg: AdamConfig::default(), lr: 3e-3 },
        use_wus: true,                                // §2 weight-update sharding
        gradsum: GradSumMode::Pipelined { quantum: 4096 }, // §2 pipelined 2-D gradsum
        backend: BackendChoice::Reference,
        batch_override: None,
        seed: 0,
        task_difficulty: 0.05,
        image_alpha: 2.0,
        quality_target: Some(0.80),
        warmup_steps: 0,
        checkpoint_every: 0,
        checkpoint_dir: None,
        resume: None,
        faults: None,
        kill_at: 0,
        exec_threads: 1,
        trace: TraceSink::disabled(),
    };
    println!("== tpu-pod-train quickstart ==");
    println!("model {}, {} cores, wus on, pipelined 2-D gradient summation", cfg.model, cfg.cores);
    let rep = train(&cfg)?;
    println!("\ninit (excluded from clock): {:.2}s", rep.init_s);
    println!("params: {}", rep.params_total);
    for (i, l) in rep.step_losses.iter().enumerate() {
        if i % 10 == 0 {
            println!("  step {:>3}: loss {:.4}", i + 1, l);
        }
    }
    for e in &rep.evals {
        println!("  eval @ {:>3}: loss {:.4}, next-token acc {:.3}", e.step, e.loss, e.accuracy);
    }
    println!("\n{}", rep.breakdown.report());
    if let Some(s) = rep.converged_at {
        println!("quality target 0.80 reached at step {s} ✓");
    }
    Ok(())
}
