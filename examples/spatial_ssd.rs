//! Spatial partitioning demo (paper §2 Fig. 3, §3 SSD):
//! 1. run a REAL stripe-partitioned convolution with halo exchange on the
//!    in-process fabric and verify it against the unpartitioned conv;
//! 2. print the SSD / Mask-RCNN partition plans with the modeled speedups
//!    (Fig. 10).
//!
//!   cargo run --release --example spatial_ssd

use tpu_pod_train::benchkit::Table;
use tpu_pod_train::devicesim::TPU_V3;
use tpu_pod_train::fabric::run_spmd;
use tpu_pod_train::netsim::{CostModel, NetParams, Torus};
use tpu_pod_train::spatial::plan::{maskrcnn_stage1_layers, plan, ssd_layers};
use tpu_pod_train::spatial::{conv2d, conv2d_striped_gather};
use tpu_pod_train::util::rng::Rng;

fn main() {
    // --- part 1: real partitioned conv ---------------------------------
    let (h, w, cin, cout, k) = (32, 16, 3, 8, 3);
    let mut rng = Rng::new(0);
    let input = rng.normal_vec(h * w * cin, 1.0);
    let weights = rng.normal_vec(k * k * cin * cout, 0.2);
    let want = conv2d(&input, h, w, cin, &weights, k, cout);
    for world in [2usize, 4] {
        let input = input.clone();
        let weights = weights.clone();
        let out = run_spmd(world, move |ep| {
            let group: Vec<usize> = (0..world).collect();
            conv2d_striped_gather(ep, &group, &input, h, w, cin, &weights, k, cout)
        });
        let max_err = out[0]
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("{world}-way stripe conv ({h}x{w}x{cin} → {cout}ch, {k}x{k}): max |err| = {max_err:.2e} ✓");
    }

    // --- part 2: partition plans + Fig. 10 speedups ---------------------
    let net = CostModel::new(Torus::new(2, 2), NetParams::default());
    let mut t = Table::new(
        "Model-parallel speedup (Fig. 10)",
        &["model", "mp=2", "mp=4", "efficiency@4"],
    );
    for (name, layers) in [("ssd", ssd_layers()), ("maskrcnn-s1", maskrcnn_stage1_layers())] {
        let p2 = plan(&layers, 2, &TPU_V3, &net);
        let p4 = plan(&layers, 4, &TPU_V3, &net);
        t.row(&[
            name.to_string(),
            format!("{:.2}x", p2.speedup()),
            format!("{:.2}x", p4.speedup()),
            format!("{:.0}%", 100.0 * p4.efficiency()),
        ]);
    }
    t.print();

    println!("\nSSD per-layer split decision at mp=4 (deep layers stop splitting — §3):");
    let p = plan(&ssd_layers(), 4, &TPU_V3, &net);
    for (l, s) in ssd_layers().iter().zip(&p.split) {
        println!(
            "  {:>4}x{:<4} {:>4}ch  k{}  {}",
            l.spatial, l.spatial, l.in_ch, l.kernel,
            if *s { "split 4-way + halo" } else { "replicated (too small)" }
        );
    }
}
