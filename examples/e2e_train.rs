//! End-to-end validation driver (DESIGN.md §Experiment E2E): train the
//! `small` transformer (~3.4M params) for a few hundred steps across 8
//! data-parallel cores with the full paper stack — AOT HLO per core,
//! pipelined 2-D gradient summation, weight-update sharding, distributed
//! padded evaluation — and log the loss curve + step breakdown.
//!
//!   cargo run --release --example e2e_train [-- --steps 300 --cores 8]
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use tpu_pod_train::coordinator::{train, GradSumMode, OptChoice, TrainConfig};
use tpu_pod_train::metrics::TraceSink;
use tpu_pod_train::optim::AdamConfig;
use tpu_pod_train::runtime::BackendChoice;
use tpu_pod_train::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("e2e_train", "end-to-end training validation")
        .opt("model", "transformer_small", "manifest model key")
        .opt("cores", "8", "data-parallel cores")
        .opt("steps", "300", "training steps")
        .opt("lr", "0.001", "Adam learning rate");
    let a = cli.parse();
    let cfg = TrainConfig {
        model: a.get_or("model", "transformer_small"),
        cores: a.get_usize("cores", 8),
        steps: a.get_usize("steps", 300),
        eval_every: 50,
        eval_examples: 512,
        opt: OptChoice::Adam { cfg: AdamConfig::default(), lr: a.get_f64("lr", 1e-3) as f32 },
        use_wus: true,
        gradsum: GradSumMode::Pipelined { quantum: 8192 },
        backend: BackendChoice::Reference,
        batch_override: None,
        seed: 42,
        task_difficulty: 0.05,
        image_alpha: 2.0,
        quality_target: Some(0.85),
        warmup_steps: 0,
        checkpoint_every: 0,
        checkpoint_dir: None,
        resume: None,
        faults: None,
        kill_at: 0,
        exec_threads: 1,
        trace: TraceSink::disabled(),
    };
    println!("== e2e_train: {} on {} cores, {} steps ==", cfg.model, cfg.cores, cfg.steps);
    let rep = train(&cfg)?;
    println!("params: {} | init {:.1}s | wall {:.1}s | exec {:.1}s",
             rep.params_total, rep.init_s, rep.wallclock_s, rep.exec_s);
    println!("{}", rep.breakdown.report());
    println!("\nloss curve (mean per 10 steps):");
    for (i, chunk) in rep.step_losses.chunks(10).enumerate() {
        let mean: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
        println!("  {:>4}: {:.4}", i * 10 + 1, mean);
    }
    println!("\nevals:");
    for e in &rep.evals {
        println!("  step {:>4}: eval loss {:.4}, next-token acc {:.3}", e.step, e.loss, e.accuracy);
    }
    match rep.converged_at {
        Some(s) => println!("\nconverged (acc ≥ 0.85) at step {s} ✓"),
        None => println!("\ndid not reach 0.85 within {} steps", cfg.steps),
    }
    // Throughput summary.
    let tokens_per_step = 8.0 * 128.0 * rep.breakdown.steps as f64; // B*S per core-step
    let _ = tokens_per_step;
    let steps_per_s = rep.breakdown.steps as f64 / rep.wallclock_s;
    println!("throughput: {:.2} global steps/s ({} cores)", steps_per_s, cfg.cores);
    Ok(())
}
