use tpu_pod_train::benchkit::Bench;
use tpu_pod_train::collectives::{gradsum_pipelined, gradsum_serial, torus2d_all_reduce, Placement};
use tpu_pod_train::fabric::run_spmd;
use tpu_pod_train::netsim::cost::resnet50_gradient_bytes;
fn main() {
    let sizes: Vec<usize> = resnet50_gradient_bytes().iter().map(|b| ((b/4.0/16.0) as usize).max(1)).collect();
    let total: usize = sizes.iter().sum();
    let world = 8;
    let mut bench = Bench::quick();
    let s = sizes.clone();
    bench.run("per-tensor 2D AR (161 tensors)", move || {
        let sizes = s.clone();
        run_spmd(world, move |ep| {
            let place = Placement::new(world);
            let mut tensors: Vec<Vec<f32>> = sizes.iter().map(|&n| vec![1.0; n]).collect();
            gradsum_serial(ep, &place, &mut tensors);
        });
    });
    bench.run("single fused 2D AR (flat buffer)", move || {
        run_spmd(world, move |ep| {
            let place = Placement::new(world);
            let mut data = vec![1.0f32; total];
            torus2d_all_reduce(ep, &place, &mut data);
        });
    });
    for q in [4096usize, 65536, 1<<20] {
        let s = sizes.clone();
        bench.run(&format!("pipelined q={q}"), move || {
            let sizes = s.clone();
            run_spmd(world, move |ep| {
                let place = Placement::new(world);
                let mut tensors: Vec<Vec<f32>> = sizes.iter().map(|&n| vec![1.0; n]).collect();
                gradsum_pipelined(ep, &place, &mut tensors, q);
            });
        });
    }
}
