//! LARS optimizer study (paper §3 Table 1, Figs. 5/6): train the mini-CNN
//! with the scaled-momentum (MLPerf-0.6 reference) and unscaled-momentum
//! (You et al.) LARS variants — plus a tuned-momentum unscaled run — and
//! report steps-to-target, the real counterpart of Table 1's epoch column.
//!
//!   cargo run --release --example lars_study

use tpu_pod_train::benchkit::Table;
use tpu_pod_train::coordinator::{train, GradSumMode, OptChoice, TrainConfig};
use tpu_pod_train::metrics::TraceSink;
use tpu_pod_train::optim::{LarsConfig, LarsVariant};
use tpu_pod_train::runtime::BackendChoice;

fn run(variant: LarsVariant, momentum: f32, lr: f32) -> (Option<usize>, f64) {
    let cfg = TrainConfig {
        model: "cnn_mini".into(),
        cores: 2,
        steps: 400,
        eval_every: 5,
        eval_examples: 512,
        opt: OptChoice::Lars {
            cfg: LarsConfig { variant, momentum, ..Default::default() },
            lr,
        },
        use_wus: true,
        gradsum: GradSumMode::Pipelined { quantum: 4096 },
        backend: BackendChoice::Reference,
        batch_override: None,
        seed: 7,
        // Hard task (low signal) + warmup/decay schedule: the regime where
        // the momentum-scaling difference between Figs. 5 and 6 matters.
        task_difficulty: 0.0,
        image_alpha: 0.3,
        quality_target: Some(0.70),
        warmup_steps: 80,
        checkpoint_every: 0,
        checkpoint_dir: None,
        resume: None,
        faults: None,
        kill_at: 0,
        exec_threads: 1,
        trace: TraceSink::disabled(),
    };
    let rep = train(&cfg).expect("train failed");
    let best = rep.evals.iter().map(|e| e.accuracy).fold(0.0, f64::max);
    (rep.converged_at, best)
}

fn main() {
    println!("LARS variants on cnn_mini (target: 70% top-1, alpha=0.3, warmup+poly decay)");
    let mut t = Table::new(
        "Table 1 analogue: steps to 70% top-1",
        &["optimizer", "momentum", "steps to target", "best acc"],
    );
    for (label, variant, momentum, lr) in [
        ("scaled momentum (MLPerf ref)", LarsVariant::Scaled, 0.9, 1.0f32),
        ("unscaled momentum", LarsVariant::Unscaled, 0.9, 1.0),
        ("unscaled + tuned momentum", LarsVariant::Unscaled, 0.929, 1.0),
    ] {
        let (steps, best) = run(variant, momentum, lr);
        t.row(&[
            label.to_string(),
            format!("{momentum}"),
            steps.map(|s| s.to_string()).unwrap_or_else(|| "DNF".into()),
            format!("{best:.3}"),
        ]);
    }
    t.print();
    println!("\n(Paper Table 1: scaled 72.8 epochs / unscaled 70.6 / tuned 64 on ImageNet @32K.)");
}
