//! Simulated MLPerf-0.6 submission: runs the pod simulator for all five
//! models across pod slices and prints the Fig. 9-style scaling table plus
//! the §2 optimization ablation at the largest scale.
//!
//!   cargo run --release --example mlperf_submission

use tpu_pod_train::benchkit::Table;
use tpu_pod_train::models::all_models;
use tpu_pod_train::simulator::{simulate, SimOptions};

fn main() {
    let slices = [64usize, 128, 256, 512, 1024, 2048];
    let mut t = Table::new(
        "MLPerf-0.6 benchmark seconds vs TPU-v3 cores (simulated, Fig. 9)",
        &["model", "64", "128", "256", "512", "1024", "2048"],
    );
    for m in all_models() {
        let mut row = vec![m.name.to_string()];
        for &cores in &slices {
            if cores > m.max_useful_cores() {
                row.push("—".into());
                continue;
            }
            let r = simulate(&m, cores, &SimOptions::default());
            row.push(if r.converged { format!("{:.0}", r.benchmark_seconds) } else { "DNF".into() });
        }
        t.row(&row);
    }
    t.print();

    let mut t2 = Table::new(
        "§2 ablation at largest scale (seconds; 'off' = that optimization disabled)",
        &["model", "all on", "no pipeline", "1-D gradsum", "no WUS", "side-card eval"],
    );
    for m in all_models() {
        let cores = m.max_useful_cores().min(2048);
        let base = simulate(&m, cores, &SimOptions::default()).benchmark_seconds;
        let f = |o: SimOptions| format!("{:.0}", simulate(&m, cores, &o).benchmark_seconds);
        t2.row(&[
            m.name.to_string(),
            format!("{base:.0}"),
            f(SimOptions { gradsum_pipelined: false, ..Default::default() }),
            f(SimOptions { gradsum_2d: false, ..Default::default() }),
            f(SimOptions { weight_update_sharding: false, ..Default::default() }),
            f(SimOptions { distributed_eval: false, ..Default::default() }),
        ]);
    }
    t2.print();
}
